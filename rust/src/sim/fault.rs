//! Deterministic fault injection for the fabric DES (§Fault in the module
//! essay).
//!
//! A [`FaultPlan`] is a *schedule-time* description of hardware failures:
//! HBM-channel outage windows, channel service-rate derating windows (FIFO
//! occupancy multipliers), NoC bus/link slowdowns, and whole-tile (PE)
//! death at a cycle. Plans are plain data — built explicitly, parsed from a
//! CLI spec ([`FaultPlan::parse`]), or generated from a seed
//! ([`FaultPlan::seeded`]) — and are resolved against a concrete
//! [`Program`] into per-resource modifier tables ([`ResolvedFaults`])
//! consulted by the engine when it schedules each op.
//!
//! Determinism is the design constraint. Every fault decision is a pure
//! function of (the op's fields, the owning resource's local FIFO cursor,
//! the epoch timestamp `now`, the plan): an outage window pushes the
//! computed start past the window's end, a derate window multiplies the
//! occupancy, and a tile death kills any op of that tile whose ready time
//! has reached the death cycle (it is simply never scheduled, so its
//! dependents never settle). No decision reads global engine state, so the
//! serial and sharded-parallel engines — which by construction agree on
//! per-resource cursor state and epoch times (§Shard) — make identical
//! fault decisions, and the PR-5 serial ≡ parallel bit-identity survives
//! injection (`tests/fault_differential.rs`).
//!
//! Resolution leans on two repo-wide invariants: HBM channel `c` is always
//! `ResourceId(c)` (every dataflow builder allocates channel resources
//! first — debug-asserted in `dataflow::flash`/`flat`), and NoC bus
//! resources are exactly those whose ops carry a fabric component
//! (`noc::is_fabric_component`). Tile deaths key on `Op::tile`. Under
//! symmetry folding a non-representative private chain is collapsed into
//! delay ops, so a death targeting a folded-away tile only lands on the
//! ops that still carry that tile id; target representative tiles (band
//! row 0 of a scheduler slot) or disable folding for precise PE-death
//! studies. The router preempts the whole band either way.

use std::collections::HashMap;

use super::program::Program;
use super::Cycle;
use crate::noc::is_fabric_component;
use crate::util::Rng;

/// An HBM channel that serves no requests during `[from, until)`; work
/// arriving in the window waits for the channel to come back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelOutage {
    /// Global HBM channel index.
    pub channel: u32,
    /// Window start (inclusive, cycles).
    pub from: Cycle,
    /// Window end (exclusive, cycles).
    pub until: Cycle,
}

/// An HBM channel running derated during `[from, until)`: occupancy of ops
/// starting inside the window is multiplied by `num/den` (rounded up).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelDerate {
    /// Global HBM channel index.
    pub channel: u32,
    /// Window start (inclusive, cycles).
    pub from: Cycle,
    /// Window end (exclusive, cycles).
    pub until: Cycle,
    /// Slowdown numerator (occupancy scales by `num/den`).
    pub num: u64,
    /// Slowdown denominator.
    pub den: u64,
}

/// Every NoC row/column bus running derated during `[from, until)` by
/// `num/den` (fabric congestion, link-level retransmission).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NocSlowdown {
    /// Window start (inclusive, cycles).
    pub from: Cycle,
    /// Window end (exclusive, cycles).
    pub until: Cycle,
    /// Slowdown numerator (occupancy scales by `num/den`).
    pub num: u64,
    /// Slowdown denominator.
    pub den: u64,
}

/// A whole tile (PE) dying at cycle `at`: none of its ops whose ready time
/// has reached `at` ever issues.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileDeath {
    /// Flat tile id.
    pub tile: u32,
    /// Death time (cycles).
    pub at: Cycle,
}

/// A deterministic set of timed hardware faults. [`FaultPlan::none`] is
/// the empty plan and reproduces fault-free schedules bit-for-bit.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Channel outage windows.
    pub outages: Vec<ChannelOutage>,
    /// Channel derate windows.
    pub derates: Vec<ChannelDerate>,
    /// Fabric-wide NoC slowdown windows.
    pub noc: Vec<NocSlowdown>,
    /// Tile deaths.
    pub deaths: Vec<TileDeath>,
}

impl FaultPlan {
    /// The empty plan: injection with it is bit-identical to no injection.
    pub fn none() -> Self {
        Self::default()
    }

    /// True for the empty plan.
    pub fn is_none(&self) -> bool {
        self.outages.is_empty()
            && self.derates.is_empty()
            && self.noc.is_empty()
            && self.deaths.is_empty()
    }

    /// Add a channel outage over `[from, until)`.
    pub fn with_outage(mut self, channel: u32, from: Cycle, until: Cycle) -> Self {
        assert!(from < until, "outage window must be non-empty");
        self.outages.push(ChannelOutage { channel, from, until });
        self
    }

    /// Add a channel derate (`num/den >= 1`) over `[from, until)`.
    pub fn with_derate(
        mut self,
        channel: u32,
        from: Cycle,
        until: Cycle,
        num: u64,
        den: u64,
    ) -> Self {
        assert!(from < until, "derate window must be non-empty");
        assert!(den > 0 && num >= den, "derate ratio must be >= 1");
        self.derates.push(ChannelDerate { channel, from, until, num, den });
        self
    }

    /// Add a fabric-wide NoC slowdown (`num/den >= 1`) over `[from, until)`.
    pub fn with_noc_slowdown(mut self, from: Cycle, until: Cycle, num: u64, den: u64) -> Self {
        assert!(from < until, "NoC slowdown window must be non-empty");
        assert!(den > 0 && num >= den, "slowdown ratio must be >= 1");
        self.noc.push(NocSlowdown { from, until, num, den });
        self
    }

    /// Kill a tile at cycle `at`.
    pub fn with_tile_death(mut self, tile: u32, at: Cycle) -> Self {
        self.deaths.push(TileDeath { tile, at });
        self
    }

    /// Content fingerprint (FNV-1a), used by the coordinator's memo key so
    /// faulted and fault-free experiment results never alias.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1_0000_01b3);
            }
        };
        eat(self.outages.len() as u64);
        for o in &self.outages {
            eat(o.channel as u64);
            eat(o.from);
            eat(o.until);
        }
        eat(self.derates.len() as u64);
        for d in &self.derates {
            eat(d.channel as u64);
            eat(d.from);
            eat(d.until);
            eat(d.num);
            eat(d.den);
        }
        eat(self.noc.len() as u64);
        for s in &self.noc {
            eat(s.from);
            eat(s.until);
            eat(s.num);
            eat(s.den);
        }
        eat(self.deaths.len() as u64);
        for t in &self.deaths {
            eat(t.tile as u64);
            eat(t.at);
        }
        h
    }

    /// Translate every window `clock` cycles into the past — the router
    /// slices its absolute-virtual-time plan into per-step relative plans
    /// with this. Windows entirely before `clock` are dropped; deaths in
    /// the past clamp to cycle 0 (the tile is already dead).
    pub fn shifted(&self, clock: Cycle) -> FaultPlan {
        let win = |from: Cycle, until: Cycle| -> Option<(Cycle, Cycle)> {
            (until > clock).then(|| (from.saturating_sub(clock), until - clock))
        };
        FaultPlan {
            outages: self
                .outages
                .iter()
                .filter_map(|o| {
                    win(o.from, o.until).map(|(from, until)| ChannelOutage {
                        channel: o.channel,
                        from,
                        until,
                    })
                })
                .collect(),
            derates: self
                .derates
                .iter()
                .filter_map(|d| {
                    win(d.from, d.until).map(|(from, until)| ChannelDerate {
                        channel: d.channel,
                        from,
                        until,
                        num: d.num,
                        den: d.den,
                    })
                })
                .collect(),
            noc: self
                .noc
                .iter()
                .filter_map(|s| {
                    win(s.from, s.until).map(|(from, until)| NocSlowdown {
                        from,
                        until,
                        num: s.num,
                        den: s.den,
                    })
                })
                .collect(),
            deaths: self
                .deaths
                .iter()
                .map(|t| TileDeath { tile: t.tile, at: t.at.saturating_sub(clock) })
                .collect(),
        }
    }

    /// Tiles dead at or before `clock` (absolute time).
    pub fn dead_tiles_at(&self, clock: Cycle) -> Vec<u32> {
        let mut tiles: Vec<u32> =
            self.deaths.iter().filter(|d| d.at <= clock).map(|d| d.tile).collect();
        tiles.sort_unstable();
        tiles.dedup();
        tiles
    }

    /// Parse a CLI fault spec: semicolon-separated clauses
    ///
    /// * `off:CH@FROM-UNTIL`        — channel `CH` out during the window
    /// * `slow:CH@FROM-UNTILxN[/D]` — channel `CH` derated by `N/D`
    /// * `noc@FROM-UNTILxN[/D]`     — all NoC buses derated by `N/D`
    /// * `die:TILE@AT`              — tile `TILE` dies at cycle `AT`
    ///
    /// e.g. `slow:8@0-4000000x4;die:60@1200000`. Cycle values are virtual
    /// serving-clock cycles when passed to `schedule --faults`.
    ///
    /// ```
    /// use flatattention::sim::FaultPlan;
    ///
    /// let plan = FaultPlan::parse("slow:8@0-4000x4;die:60@1200").unwrap();
    /// assert_eq!((plan.derates.len(), plan.deaths.len()), (1, 1));
    /// assert_eq!(plan.deaths[0].tile, 60);
    /// assert!(FaultPlan::parse("explode:everything").is_err());
    /// ```
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        fn num(field: &str, s: &str) -> Result<u64, String> {
            s.trim()
                .parse::<u64>()
                .map_err(|_| format!("fault spec: field '{field}': expected an integer, got '{s}'"))
        }
        fn window(clause: &str, s: &str) -> Result<(Cycle, Cycle), String> {
            let (a, b) = s
                .split_once('-')
                .ok_or_else(|| format!("fault clause '{clause}': expected FROM-UNTIL, got '{s}'"))?;
            let (from, until) = (num("from", a)?, num("until", b)?);
            if from >= until {
                return Err(format!("fault clause '{clause}': empty window {from}-{until}"));
            }
            Ok((from, until))
        }
        fn ratio(clause: &str, s: &str) -> Result<(u64, u64), String> {
            let (num_s, den_s) = match s.split_once('/') {
                Some((n, d)) => (n, d),
                None => (s, "1"),
            };
            let (n, d) = (num("factor", num_s)?, num("factor denominator", den_s)?);
            if d == 0 || n < d {
                return Err(format!("fault clause '{clause}': factor {s} must be >= 1"));
            }
            Ok((n, d))
        }
        let mut plan = FaultPlan::none();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            if let Some(rest) = clause.strip_prefix("off:") {
                let (ch, w) = rest
                    .split_once('@')
                    .ok_or_else(|| format!("fault clause '{clause}': expected off:CH@FROM-UNTIL"))?;
                let (from, until) = window(clause, w)?;
                plan.outages.push(ChannelOutage {
                    channel: num("channel", ch)? as u32,
                    from,
                    until,
                });
            } else if let Some(rest) = clause.strip_prefix("slow:") {
                let (ch, w) = rest.split_once('@').ok_or_else(|| {
                    format!("fault clause '{clause}': expected slow:CH@FROM-UNTILxN")
                })?;
                let (w, x) = w.split_once('x').ok_or_else(|| {
                    format!("fault clause '{clause}': expected a xN derate factor")
                })?;
                let (from, until) = window(clause, w)?;
                let (n, d) = ratio(clause, x)?;
                plan.derates.push(ChannelDerate {
                    channel: num("channel", ch)? as u32,
                    from,
                    until,
                    num: n,
                    den: d,
                });
            } else if let Some(rest) = clause.strip_prefix("noc@") {
                let (w, x) = rest.split_once('x').ok_or_else(|| {
                    format!("fault clause '{clause}': expected a xN slowdown factor")
                })?;
                let (from, until) = window(clause, w)?;
                let (n, d) = ratio(clause, x)?;
                plan.noc.push(NocSlowdown { from, until, num: n, den: d });
            } else if let Some(rest) = clause.strip_prefix("die:") {
                let (tile, at) = rest
                    .split_once('@')
                    .ok_or_else(|| format!("fault clause '{clause}': expected die:TILE@AT"))?;
                let tile = num("tile", tile)? as u32;
                // Two deaths for one tile are ambiguous in a CLI spec
                // (resolve() would quietly take the earlier one) — reject
                // rather than guess the user's intent.
                if plan.deaths.iter().any(|d| d.tile == tile) {
                    return Err(format!(
                        "fault clause '{clause}': duplicate death for tile {tile} \
                         (each tile may die at most once)"
                    ));
                }
                plan.deaths.push(TileDeath { tile, at: num("at", at)? });
            } else {
                return Err(format!(
                    "fault clause '{clause}': unknown kind (expected off:/slow:/noc@/die:)"
                ));
            }
        }
        Ok(plan)
    }

    /// A seeded, reproducible plan: derates ~`severity` of `channels` by
    /// 2-4x over random sub-windows of `[0, horizon)`, and above severity
    /// 0.5 also kills one random tile mid-horizon. Same seed ⇒ same plan.
    pub fn seeded(
        seed: u64,
        channels: u32,
        tiles: u32,
        horizon: Cycle,
        severity: f64,
    ) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17_1A17);
        let mut plan = FaultPlan::none();
        let hit = ((channels as f64 * severity).round() as u32).min(channels);
        let h = horizon.max(4);
        for _ in 0..hit {
            let ch = rng.gen_range(channels as u64) as u32;
            let a = rng.gen_range(h / 2);
            let b = a + 1 + rng.gen_range(h / 2);
            let factor = 2 + rng.gen_range(3);
            plan = plan.with_derate(ch, a, b, factor, 1);
        }
        if severity > 0.5 && tiles > 0 {
            let tile = rng.gen_range(tiles as u64) as u32;
            plan = plan.with_tile_death(tile, horizon / 2);
        }
        plan
    }

    /// Resolve the logical plan against a concrete program into the
    /// per-resource tables the engine consults (§Fault).
    pub fn resolve(&self, program: &Program) -> ResolvedFaults {
        let n_res = program.num_resources() as u32;
        let mut rf = ResolvedFaults::default();
        for o in &self.outages {
            if o.channel < n_res {
                rf.outages.entry(o.channel).or_default().push((o.from, o.until));
            }
        }
        for d in &self.derates {
            if d.channel < n_res {
                rf.derates.entry(d.channel).or_default().push((d.from, d.until, d.num, d.den));
            }
        }
        if !self.noc.is_empty() {
            // NoC buses are exactly the resources carrying fabric ops.
            let mut fabric: Vec<u32> = program
                .ops()
                .iter()
                .filter(|op| is_fabric_component(op.component))
                .map(|op| op.resource.0)
                .collect();
            fabric.sort_unstable();
            fabric.dedup();
            for r in fabric {
                let ws = rf.derates.entry(r).or_default();
                for s in &self.noc {
                    ws.push((s.from, s.until, s.num, s.den));
                }
            }
        }
        for ws in rf.outages.values_mut() {
            ws.sort_unstable();
        }
        for ws in rf.derates.values_mut() {
            ws.sort_unstable();
        }
        for t in &self.deaths {
            rf.deaths
                .entry(t.tile)
                .and_modify(|at| *at = (*at).min(t.at))
                .or_insert(t.at);
        }
        rf
    }
}

/// [`FaultPlan`] resolved against one program: per-resource modifier
/// windows plus the tile death table, in the form the engine's inner
/// scheduling step consults. Lookups only — iteration order never matters.
#[derive(Debug, Clone, Default)]
pub struct ResolvedFaults {
    outages: HashMap<u32, Vec<(Cycle, Cycle)>>,
    derates: HashMap<u32, Vec<(Cycle, Cycle, u64, u64)>>,
    deaths: HashMap<u32, Cycle>,
}

impl ResolvedFaults {
    #[inline]
    pub(crate) fn outages_of(&self, resource: u32) -> Option<&[(Cycle, Cycle)]> {
        self.outages.get(&resource).map(|v| v.as_slice())
    }

    #[inline]
    pub(crate) fn derates_of(&self, resource: u32) -> Option<&[(Cycle, Cycle, u64, u64)]> {
        self.derates.get(&resource).map(|v| v.as_slice())
    }

    #[inline]
    pub(crate) fn death_of(&self, tile: u32) -> Option<Cycle> {
        self.deaths.get(&tile).copied()
    }
}

/// Outcome of a faulted execution: `killed` ops were ready but never
/// issued (their tile was dead); `stalled` ops never became ready (a
/// dependency — transitively — was killed). Both are sorted by op id, so
/// reports compare bit-for-bit across engines and thread counts.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Op ids never issued (their tile was dead), sorted.
    pub killed: Vec<u32>,
    /// Op ids stuck behind killed dependencies, sorted.
    pub stalled: Vec<u32>,
}

impl FaultReport {
    /// No op was lost: the program ran to completion despite the plan.
    pub fn is_clean(&self) -> bool {
        self.killed.is_empty() && self.stalled.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_is_empty_and_stable() {
        let p = FaultPlan::none();
        assert!(p.is_none());
        assert_eq!(p.fingerprint(), FaultPlan::none().fingerprint());
        let q = p.clone().with_derate(0, 0, 10, 2, 1);
        assert!(!q.is_none());
        assert_ne!(q.fingerprint(), p.fingerprint());
    }

    #[test]
    fn parse_round_trips_every_clause_kind() {
        let plan =
            FaultPlan::parse("off:3@100-200; slow:8@0-4000000x4; noc@50-60x3/2; die:60@1200000")
                .expect("valid spec");
        assert_eq!(plan.outages, vec![ChannelOutage { channel: 3, from: 100, until: 200 }]);
        assert_eq!(
            plan.derates,
            vec![ChannelDerate { channel: 8, from: 0, until: 4_000_000, num: 4, den: 1 }]
        );
        assert_eq!(plan.noc, vec![NocSlowdown { from: 50, until: 60, num: 3, den: 2 }]);
        assert_eq!(plan.deaths, vec![TileDeath { tile: 60, at: 1_200_000 }]);
        assert!(FaultPlan::parse("").expect("empty ok").is_none());
    }

    #[test]
    fn parse_names_the_bad_field() {
        let e = FaultPlan::parse("slow:x@0-10x2").unwrap_err();
        assert!(e.contains("channel") && e.contains("'x'"), "{e}");
        let e = FaultPlan::parse("off:0@10-10").unwrap_err();
        assert!(e.contains("empty window"), "{e}");
        let e = FaultPlan::parse("slow:0@0-10x1/2").unwrap_err();
        assert!(e.contains("factor"), "{e}");
        let e = FaultPlan::parse("boom:1@2-3").unwrap_err();
        assert!(e.contains("unknown kind"), "{e}");
    }

    #[test]
    fn parse_rejects_malformed_and_duplicate_clauses() {
        // slow: with no @window at all.
        let e = FaultPlan::parse("slow:3x2").unwrap_err();
        assert!(e.contains("expected slow:CH@FROM-UNTILxN"), "{e}");
        // Non-numeric channel on an outage clause.
        let e = FaultPlan::parse("off:ch@0-10").unwrap_err();
        assert!(e.contains("channel") && e.contains("'ch'"), "{e}");
        // Duplicate kill specs for one tile are rejected, not silently
        // collapsed; distinct tiles stay fine.
        let e = FaultPlan::parse("die:60@100;die:60@200").unwrap_err();
        assert!(e.contains("duplicate death for tile 60"), "{e}");
        let plan = FaultPlan::parse("die:60@100;die:61@200").expect("distinct tiles ok");
        assert_eq!(plan.deaths.len(), 2);
    }

    #[test]
    fn shifted_slices_windows_and_clamps_deaths() {
        let plan = FaultPlan::none()
            .with_derate(0, 100, 200, 2, 1)
            .with_outage(1, 0, 50)
            .with_tile_death(7, 120);
        let s = plan.shifted(150);
        let want = ChannelDerate { channel: 0, from: 0, until: 50, num: 2, den: 1 };
        assert_eq!(s.derates, vec![want]);
        assert!(s.outages.is_empty(), "fully-past window dropped");
        assert_eq!(s.deaths, vec![TileDeath { tile: 7, at: 0 }]);
        assert_eq!(plan.dead_tiles_at(119), Vec::<u32>::new());
        assert_eq!(plan.dead_tiles_at(120), vec![7]);
    }

    #[test]
    fn seeded_plans_are_reproducible() {
        let a = FaultPlan::seeded(42, 16, 64, 1_000_000, 0.75);
        let b = FaultPlan::seeded(42, 16, 64, 1_000_000, 0.75);
        assert_eq!(a, b);
        assert!(!a.derates.is_empty() && !a.deaths.is_empty());
        let c = FaultPlan::seeded(43, 16, 64, 1_000_000, 0.75);
        assert_ne!(a.fingerprint(), c.fingerprint());
    }
}
