//! Dependency-driven discrete-event executor.
//!
//! Executes a [`Program`] DAG: an op starts once (a) all dependencies have
//! completed and (b) its resource is free, FIFO in ready order with
//! deterministic op-id tie-breaking. Resources are released after
//! `occupancy` cycles; dependents observe completion after an additional
//! `latency` (pipelined resources like HBM channels and NoC paths keep
//! serving while earlier transfers are still in flight).
//!
//! Ops that become ready at the *same cycle* are scheduled in op-id order:
//! the loop drains every completion event of one timestamp before
//! scheduling the ops those completions released (sorted by id), instead
//! of scheduling mid-cascade. This makes equal-time tie-breaking a
//! function of the program's emission order alone — not of the incidental
//! event-cascade order — which is what lets a symmetry-folded program
//! (fewer ops, same kept-op emission order; see `crate::dataflow`)
//! reproduce the unfolded schedule bit for bit. [`crate::sim::reference`]
//! applies the identical rule.
//!
//! §Perf: the dependents CSR and initial in-degrees come from the sealed
//! [`Program`] (built once at construction; an unsealed program falls back
//! to a local derivation), and the completion-event queue is an indexed
//! radix-bucket queue ([`crate::sim::queue::EventQueue`]) tuned for the
//! near-monotonic event streams these schedules produce. The seed-derived
//! `BinaryHeap` engine lives in [`crate::sim::reference`] and
//! `tests/engine_differential.rs` proves schedule equivalence on
//! randomized DAGs. Grid-wide counters additionally fold in
//! [`Program::fold`] — the accounting of ops elided by symmetry folding.

use super::breakdown::{Breakdown, Component, RunStats};
use super::program::Program;
use super::queue::EventQueue;
use super::Cycle;

/// One executed-op record for trace export: `(op index, start, complete)`.
pub type TraceRecord = (u32, Cycle, Cycle);

/// Execute `program`, tracking breakdown intervals for `tracked_tile`.
///
/// Panics if the program contains a dependency cycle (impossible for
/// builder-constructed programs, which are topologically ordered).
pub fn execute(program: &Program, tracked_tile: u32) -> RunStats {
    execute_traced(program, tracked_tile, None).0
}

/// Like [`execute`], optionally recording `(op, start, complete)` for every
/// op whose owner tile is `< trace_tile_limit` (see [`crate::sim::trace`]).
pub fn execute_traced(
    program: &Program,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
) -> (RunStats, Vec<TraceRecord>) {
    let ops = program.ops();
    let n = ops.len();

    // Dependents adjacency + initial in-degrees: reuse the sealed CSR, or
    // derive locally for hand-built programs that skipped `seal`.
    let local_csr;
    let (out_start, out_edges, indeg0): (&[u32], &[u32], &[u32]) = if program.is_sealed() {
        (&program.out_start, &program.out_edges, &program.indeg0)
    } else {
        local_csr = program.build_dependents_csr();
        (&local_csr.0, &local_csr.1, &local_csr.2)
    };
    let mut indeg: Vec<u32> = indeg0.to_vec();

    // Resources reduce to *cursors*: service is FIFO in ready order and
    // every op's duration is known up front, so an op can be scheduled the
    // moment it becomes ready, at `start = max(ready, resource_free)` —
    // later-ready ops can only queue behind (FIFO), never preempt. This
    // removes per-resource queues and wake-up events entirely: the event
    // queue holds exactly one completion per op (§Perf).
    let nr = program.num_resources();
    let mut res_free: Vec<Cycle> = vec![0; nr];

    // Completion events keyed by time; the queue pops equal-time events in
    // push order, matching the seed heap's insertion-seq tie-breaking.
    let mut events = EventQueue::new();

    // Accounting.
    let mut makespan: Cycle = 0;
    let mut hbm_bytes: u64 = 0;
    let mut redmule_busy: Cycle = 0;
    let mut spatz_busy: Cycle = 0;
    let mut executed: usize = 0;
    let mut intervals: Vec<(Component, Cycle, Cycle)> = Vec::new();
    let mut trace: Vec<TraceRecord> = Vec::new();

    // Schedule op `$idx`, ready at `$now`, on its resource cursor.
    // Breakdown attribution (tracked tile only): memory/fabric ops are
    // charged from their *issue* time (the tile is blocked on the shared
    // channel/bus from the moment its DMA is ready); compute ops from
    // their actual start (engine-queue wait is the other stream's overlap,
    // not this component's cost).
    macro_rules! schedule {
        ($idx:expr, $now:expr) => {{
            let op_idx: u32 = $idx;
            let op = &ops[op_idx as usize];
            let r = op.resource.0 as usize;
            let start = res_free[r].max($now);
            let released = start + op.occupancy;
            let complete = released + op.latency;
            res_free[r] = released;
            events.push(complete, op_idx);
            match op.component {
                Component::RedMule => redmule_busy += op.occupancy,
                Component::Spatz => spatz_busy += op.occupancy,
                _ => {}
            }
            hbm_bytes += op.hbm_bytes;
            if op.tile == tracked_tile && complete > $now {
                let from = match op.component {
                    Component::HbmAccess
                    | Component::Multicast
                    | Component::MaxReduce
                    | Component::SumReduce => $now,
                    _ => start,
                };
                intervals.push((op.component, from, complete));
            }
            if let Some(limit) = trace_tile_limit {
                if op.tile < limit {
                    trace.push((op_idx, start, complete));
                }
            }
            executed += 1;
            makespan = makespan.max(complete);
        }};
    }

    // Collect the dependents released by completion of op `$idx`.
    macro_rules! settle {
        ($idx:expr, $ready:ident) => {{
            let i = $idx as usize;
            let (s, e) = (out_start[i] as usize, out_start[i + 1] as usize);
            for &dep_idx in &out_edges[s..e] {
                let di = dep_idx as usize;
                indeg[di] -= 1;
                if indeg[di] == 0 {
                    $ready.push(dep_idx);
                }
            }
        }};
    }

    // Seed: all zero-indegree ops are ready at cycle 0, in op-id order.
    for (i, &d) in indeg.iter().enumerate() {
        if d == 0 {
            schedule!(i as u32, 0);
        }
    }

    // Main loop: drain every completion event of one timestamp, then
    // schedule the released ops in op-id order. Zero-duration ops
    // scheduled here complete at the same timestamp and are handled as a
    // further batch on the next iteration.
    let mut completed = 0usize;
    let mut ready_buf: Vec<u32> = Vec::new();
    while let Some((now, idx)) = events.pop() {
        ready_buf.clear();
        completed += 1;
        settle!(idx, ready_buf);
        while let Some((t, _)) = events.peek() {
            if t != now {
                break;
            }
            let (_, idx2) = events.pop().expect("peeked event exists");
            completed += 1;
            settle!(idx2, ready_buf);
        }
        ready_buf.sort_unstable();
        for &op_idx in &ready_buf {
            schedule!(op_idx, now);
        }
    }

    assert_eq!(
        completed, n,
        "dependency cycle: {} of {} ops never became ready",
        n - completed,
        n
    );

    let fold = program.fold;
    let breakdown = Breakdown::from_intervals(&intervals, makespan);
    (
        RunStats {
            makespan,
            breakdown,
            hbm_bytes,
            flops: program.flops,
            redmule_busy_total: redmule_busy + fold.redmule_busy,
            spatz_busy_total: spatz_busy + fold.spatz_busy,
            ops_executed: executed + fold.ops as usize,
        },
        trace,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::program::{Op, NO_TILE};

    #[test]
    fn serial_chain_on_one_resource() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(r, 20, 0, Component::RedMule, 0, 0, &[a]);
        let _ = p.op(r, 5, 0, Component::RedMule, 0, 0, &[b]);
        let st = execute(&p, 0);
        assert_eq!(st.makespan, 35);
        assert_eq!(st.breakdown.redmule, 35);
        assert_eq!(st.redmule_busy_total, 35);
    }

    #[test]
    fn independent_ops_on_distinct_resources_overlap() {
        let mut p = Program::new();
        let r1 = p.resource();
        let r2 = p.resource();
        p.op(r1, 100, 0, Component::RedMule, 0, 0, &[]);
        p.op(r2, 60, 0, Component::Spatz, 0, 0, &[]);
        let st = execute(&p, 0);
        // Spatz fully overlapped by RedMulE on the tracked tile.
        assert_eq!(st.makespan, 100);
        assert_eq!(st.breakdown.redmule, 100);
        assert_eq!(st.breakdown.spatz, 0);
    }

    #[test]
    fn resource_contention_serializes() {
        let mut p = Program::new();
        let r = p.resource();
        for _ in 0..4 {
            p.op(r, 25, 0, Component::HbmAccess, 0, 0, &[]);
        }
        let st = execute(&p, 0);
        assert_eq!(st.makespan, 100);
    }

    #[test]
    fn latency_pipelines_but_occupancy_serializes() {
        // Two HBM transfers on one channel: occupancy 10 each, latency 200.
        // Second starts at t=10 (channel free), completes 10+10+200=220.
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 200, Component::HbmAccess, 0, 64, &[]);
        p.op(r, 10, 200, Component::HbmAccess, 0, 64, &[]);
        let st = execute(&p, 0);
        assert_eq!(st.makespan, 220);
        assert_eq!(st.hbm_bytes, 128);
    }

    #[test]
    fn dependency_with_latency() {
        let mut p = Program::new();
        let r1 = p.resource();
        let r2 = p.resource();
        let a = p.op(r1, 10, 50, Component::Multicast, 0, 0, &[]);
        let b = p.op(r2, 5, 0, Component::RedMule, 0, 0, &[a]);
        let st = execute(&p, 0);
        // b starts at a's completion (60), ends 65.
        assert_eq!(st.makespan, 65);
        let _ = b;
    }

    #[test]
    fn fifo_ready_order_is_deterministic() {
        // Three ops become ready at the same time on one resource: executed
        // in op-id order.
        let mut p = Program::new();
        let r0 = p.resource();
        let r = p.resource();
        let gate = p.op(r0, 7, 0, Component::Other, NO_TILE, 0, &[]);
        let a = p.op(r, 10, 0, Component::RedMule, 0, 0, &[gate]);
        let b = p.op(r, 10, 0, Component::Spatz, 0, 0, &[gate]);
        // Downstream op depends on b only; if order were swapped its start
        // would change.
        let c = p.op(r0, 1, 0, Component::Other, NO_TILE, 0, &[b]);
        let st = execute(&p, 0);
        // gate [0,7); a [7,17); b [17,27); c [27,28).
        assert_eq!(st.makespan, 28);
        let _ = (a, c);
    }

    #[test]
    fn barrier_joins_parallel_streams() {
        let mut p = Program::new();
        let rs = p.resources(4);
        let sync = p.resource();
        let mut ids = Vec::new();
        for (i, &r) in rs.iter().enumerate() {
            ids.push(p.op(r, 10 * (i as u64 + 1), 0, Component::RedMule, i as u32, 0, &[]));
        }
        let bar = p.op(sync, 0, 0, Component::Other, NO_TILE, 0, &ids);
        let after = p.op(rs[0], 5, 0, Component::Spatz, 0, 0, &[bar]);
        let st = execute(&p, 0);
        assert_eq!(st.makespan, 45); // slowest stream 40 + 5
        let _ = after;
    }

    #[test]
    fn stats_flops_passthrough() {
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        p.flops = 12345;
        let st = execute(&p, 0);
        assert_eq!(st.flops, 12345);
        assert_eq!(st.ops_executed, 1);
    }

    #[test]
    fn fold_accounting_joins_linear_counters() {
        use crate::sim::program::FoldStats;
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        p.fold = FoldStats { ops: 5, redmule_busy: 100, spatz_busy: 50, streams: 2 };
        let st = execute(&p, 0);
        assert_eq!(st.ops_executed, 6);
        assert_eq!(st.redmule_busy_total, 110);
        assert_eq!(st.spatz_busy_total, 50);
        // The reference engine applies the identical accounting.
        assert_eq!(crate::sim::execute_reference(&p, 0), st);
    }

    #[test]
    fn sealed_and_unsealed_execution_agree() {
        let mut p = Program::new();
        let r = p.resources(3);
        let a = p.op(r[0], 9, 3, Component::HbmAccess, 0, 128, &[]);
        let b = p.op(r[1], 4, 0, Component::RedMule, 0, 0, &[a]);
        let c = p.op(r[2], 6, 1, Component::Spatz, 1, 0, &[a]);
        let _ = p.op(r[0], 2, 0, Component::Other, NO_TILE, 0, &[b, c]);
        let unsealed = execute(&p, 0);
        p.seal();
        let sealed = execute(&p, 0);
        assert_eq!(unsealed, sealed);
    }

    #[test]
    #[should_panic(expected = "dependency cycle")]
    fn dependency_cycle_panics() {
        // `Program::op` cannot express a cycle (deps must precede the op),
        // so build one manually: op 0 ⇄ op 1.
        let mut p = Program::new();
        let r = p.resource();
        let proto = |deps_start: u32| Op {
            resource: r,
            occupancy: 1,
            latency: 0,
            component: Component::Other,
            tile: NO_TILE,
            hbm_bytes: 0,
            deps_start,
            deps_len: 1,
        };
        p.deps_pool.push(1);
        p.ops.push(proto(0));
        p.deps_pool.push(0);
        p.ops.push(proto(1));
        execute(&p, 0);
    }
}
