//! Dependency-driven discrete-event executor.
//!
//! Executes a [`Program`] DAG: an op starts once (a) all dependencies have
//! completed and (b) its resource is free, FIFO in ready order with
//! deterministic op-id tie-breaking. Resources are released after
//! `occupancy` cycles; dependents observe completion after an additional
//! `latency` (pipelined resources like HBM channels and NoC paths keep
//! serving while earlier transfers are still in flight).
//!
//! Ops that become ready at the *same cycle* are scheduled in op-id order:
//! the loop drains every completion event of one timestamp before
//! scheduling the ops those completions released (sorted by id), instead
//! of scheduling mid-cascade. This makes equal-time tie-breaking a
//! function of the program's emission order alone — not of the incidental
//! event-cascade order — which is what lets a symmetry-folded program
//! (fewer ops, same kept-op emission order; see `crate::dataflow`)
//! reproduce the unfolded schedule bit for bit. [`crate::sim::reference`]
//! applies the identical rule.
//!
//! §Perf: the dependents CSR and initial in-degrees come from the sealed
//! [`Program`] (built once at construction; an unsealed program falls back
//! to a local derivation), and the completion-event queue is an indexed
//! radix-bucket queue ([`crate::sim::queue::EventQueue`]) tuned for the
//! near-monotonic event streams these schedules produce. The seed-derived
//! `BinaryHeap` engine lives in [`crate::sim::reference`] and
//! `tests/engine_differential.rs` proves schedule equivalence on
//! randomized DAGs. Grid-wide counters additionally fold in
//! [`Program::fold`] — the accounting of ops elided by symmetry folding.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::breakdown::{Breakdown, Component, RunStats};
use super::fault::{FaultPlan, FaultReport, ResolvedFaults};
use super::program::Program;
use super::queue::EventQueue;
use super::Cycle;

/// One executed-op record for trace export: `(op index, start, complete)`.
pub type TraceRecord = (u32, Cycle, Cycle);

/// Execute `program`, tracking breakdown intervals for `tracked_tile`.
///
/// Panics if the program contains a dependency cycle (impossible for
/// builder-constructed programs, which are topologically ordered).
pub fn execute(program: &Program, tracked_tile: u32) -> RunStats {
    execute_traced(program, tracked_tile, None).0
}

/// Like [`execute`], optionally recording `(op, start, complete)` for every
/// op whose owner tile is `< trace_tile_limit` (see [`crate::sim::trace`]).
pub fn execute_traced(
    program: &Program,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
) -> (RunStats, Vec<TraceRecord>) {
    let (stats, trace, fr) = execute_core(program, tracked_tile, trace_tile_limit, None);
    if !fr.stalled.is_empty() {
        stall_panic(program, &fr);
    }
    (stats, trace)
}

/// Execute under a [`FaultPlan`] (§Fault in the module essay): channel
/// outage windows push affected starts past the window, derate windows
/// multiply occupancy, and tile deaths drop ops — whose dependents then
/// never settle. Unlike the fault-free entry points this never panics on a
/// drained queue: lost ops come back in the [`FaultReport`].
///
/// `threads > 1` runs the sharded engine; every fault decision is a pure
/// function of per-resource cursor state and the epoch timestamp, both of
/// which the §Shard partition reproduces exactly, so results are
/// bit-identical at every thread count — and `FaultPlan::none()`
/// reproduces [`execute`] bit for bit (`tests/fault_differential.rs`).
pub fn execute_faulted(
    program: &Program,
    tracked_tile: u32,
    plan: &FaultPlan,
    threads: usize,
) -> (RunStats, FaultReport) {
    let (stats, _, fr) = execute_faulted_traced(program, tracked_tile, None, plan, threads);
    (stats, fr)
}

/// Traced variant of [`execute_faulted`]; killed ops are never scheduled,
/// so they emit no trace records.
pub fn execute_faulted_traced(
    program: &Program,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
    plan: &FaultPlan,
    threads: usize,
) -> (RunStats, Vec<TraceRecord>, FaultReport) {
    let rf = plan.resolve(program);
    if threads.max(1) == 1 || !program.is_sealed() || program.num_shards() <= 1 {
        execute_core(program, tracked_tile, trace_tile_limit, Some(&rf))
    } else {
        execute_parallel_core(program, tracked_tile, trace_tile_limit, threads, Some(&rf))
    }
}

/// Diagnose a drained event queue with unsettled ops: a dependency cycle in
/// a hand-built DAG, or dependencies lost to tile-death faults reaching a
/// fault-free entry point. Reports the stuck op ids with their resources
/// and owning shards instead of a bare count.
fn stall_panic(program: &Program, fr: &FaultReport) -> ! {
    panic!("dependency cycle or lost dependency: {}", stall_diagnostics(program, fr));
}

/// Crate-visible so the telemetry layer can route the same diagnostics into
/// the serving-run event stream instead of only panicking to stderr.
pub(crate) fn stall_diagnostics(program: &Program, fr: &FaultReport) -> String {
    let shard_of = program.op_shards();
    let describe = |ids: &[u32]| -> String {
        let mut s = String::new();
        for (k, &i) in ids.iter().take(8).enumerate() {
            if k > 0 {
                s.push_str(", ");
            }
            let op = &program.ops()[i as usize];
            let shard = shard_of
                .get(i as usize)
                .map_or_else(|| "unsealed".to_string(), |sh| sh.to_string());
            s.push_str(&format!(
                "op {i} (resource {}, shard {shard}, {:?}, tile {})",
                op.resource.0, op.component, op.tile
            ));
        }
        if ids.len() > 8 {
            s.push_str(&format!(" … +{} more", ids.len() - 8));
        }
        s
    };
    let mut msg = format!(
        "{} of {} ops never became ready; stuck: {}",
        fr.stalled.len(),
        program.num_ops(),
        describe(&fr.stalled)
    );
    if !fr.killed.is_empty() {
        msg.push_str(&format!("; killed by tile death: {}", describe(&fr.killed)));
    }
    msg
}

/// Shared serial core. With `faults == None` this is the exact historical
/// schedule; with a resolved plan, the per-resource cursor arithmetic
/// gains the window modifiers and tile deaths drop ops (§Fault).
fn execute_core(
    program: &Program,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
    faults: Option<&ResolvedFaults>,
) -> (RunStats, Vec<TraceRecord>, FaultReport) {
    let ops = program.ops();
    let n = ops.len();

    // Dependents adjacency + initial in-degrees: reuse the sealed CSR, or
    // derive locally for hand-built programs that skipped `seal`.
    let local_csr;
    let (out_start, out_edges, indeg0): (&[u32], &[u32], &[u32]) = if program.is_sealed() {
        (&program.out_start, &program.out_edges, &program.indeg0)
    } else {
        local_csr = program.build_dependents_csr();
        (&local_csr.0, &local_csr.1, &local_csr.2)
    };
    let mut indeg: Vec<u32> = indeg0.to_vec();

    // Resources reduce to *cursors*: service is FIFO in ready order and
    // every op's duration is known up front, so an op can be scheduled the
    // moment it becomes ready, at `start = max(ready, resource_free)` —
    // later-ready ops can only queue behind (FIFO), never preempt. This
    // removes per-resource queues and wake-up events entirely: the event
    // queue holds exactly one completion per op (§Perf).
    let nr = program.num_resources();
    let mut res_free: Vec<Cycle> = vec![0; nr];

    // Completion events keyed by time; the queue pops equal-time events in
    // push order, matching the seed heap's insertion-seq tie-breaking.
    let mut events = EventQueue::new();

    // Accounting.
    let mut makespan: Cycle = 0;
    let mut hbm_bytes: u64 = 0;
    let mut redmule_busy: Cycle = 0;
    let mut spatz_busy: Cycle = 0;
    let mut executed: usize = 0;
    let mut intervals: Vec<(Component, Cycle, Cycle)> = Vec::new();
    let mut trace: Vec<TraceRecord> = Vec::new();
    let mut killed: Vec<u32> = Vec::new();

    // Schedule op `$idx`, ready at `$now`, on its resource cursor.
    // Breakdown attribution (tracked tile only): memory/fabric ops are
    // charged from their *issue* time (the tile is blocked on the shared
    // channel/bus from the moment its DMA is ready); compute ops from
    // their actual start (engine-queue wait is the other stream's overlap,
    // not this component's cost).
    //
    // Fault handling (§Fault): a dead tile's op is dropped instead of
    // scheduled (no completion event — dependents stall); an outage window
    // containing the computed start pushes it past the window's end
    // (cascading through later windows, which are sorted); the first
    // derate window containing the start multiplies the occupancy. All of
    // it reads only op fields, this resource's cursor and `$now`, so the
    // sharded engine reproduces each decision exactly.
    macro_rules! schedule {
        ($idx:expr, $now:expr) => {{
            let op_idx: u32 = $idx;
            let op = &ops[op_idx as usize];
            let dead =
                faults.and_then(|f| f.death_of(op.tile)).is_some_and(|at| $now >= at);
            if dead {
                killed.push(op_idx);
            } else {
                let r = op.resource.0 as usize;
                let mut start = res_free[r].max($now);
                let mut occupancy = op.occupancy;
                if let Some(f) = faults {
                    if let Some(ws) = f.outages_of(op.resource.0) {
                        for &(from, until) in ws {
                            if start >= from && start < until {
                                start = until;
                            }
                        }
                    }
                    if let Some(ws) = f.derates_of(op.resource.0) {
                        for &(from, until, num, den) in ws {
                            if start >= from && start < until {
                                occupancy = occupancy.saturating_mul(num).div_ceil(den);
                                break;
                            }
                        }
                    }
                }
                let released = start + occupancy;
                let complete = released + op.latency;
                res_free[r] = released;
                events.push(complete, op_idx);
                match op.component {
                    Component::RedMule => redmule_busy += occupancy,
                    Component::Spatz => spatz_busy += occupancy,
                    _ => {}
                }
                hbm_bytes += op.hbm_bytes;
                if op.tile == tracked_tile && complete > $now {
                    let from = match op.component {
                        Component::HbmAccess
                        | Component::Multicast
                        | Component::MaxReduce
                        | Component::SumReduce => $now,
                        _ => start,
                    };
                    intervals.push((op.component, from, complete));
                }
                if let Some(limit) = trace_tile_limit {
                    if op.tile < limit {
                        trace.push((op_idx, start, complete));
                    }
                }
                executed += 1;
                makespan = makespan.max(complete);
            }
        }};
    }

    // Collect the dependents released by completion of op `$idx`.
    macro_rules! settle {
        ($idx:expr, $ready:ident) => {{
            let i = $idx as usize;
            let (s, e) = (out_start[i] as usize, out_start[i + 1] as usize);
            for &dep_idx in &out_edges[s..e] {
                let di = dep_idx as usize;
                indeg[di] -= 1;
                if indeg[di] == 0 {
                    $ready.push(dep_idx);
                }
            }
        }};
    }

    // Seed: all zero-indegree ops are ready at cycle 0, in op-id order.
    for (i, &d) in indeg.iter().enumerate() {
        if d == 0 {
            schedule!(i as u32, 0);
        }
    }

    // Main loop: drain every completion event of one timestamp, then
    // schedule the released ops in op-id order. Zero-duration ops
    // scheduled here complete at the same timestamp and are handled as a
    // further batch on the next iteration.
    let mut completed = 0usize;
    let mut ready_buf: Vec<u32> = Vec::new();
    while let Some((now, idx)) = events.pop() {
        ready_buf.clear();
        completed += 1;
        settle!(idx, ready_buf);
        while let Some((t, _)) = events.peek() {
            if t != now {
                break;
            }
            let (_, idx2) = events.pop().expect("peeked event exists");
            completed += 1;
            settle!(idx2, ready_buf);
        }
        ready_buf.sort_unstable();
        for &op_idx in &ready_buf {
            schedule!(op_idx, now);
        }
    }

    // Scheduled ops all completed (`completed`); the remainder were either
    // killed outright or stalled behind a killed/cyclic dependency.
    let mut fr = FaultReport { killed, stalled: Vec::new() };
    fr.killed.sort_unstable();
    if completed + fr.killed.len() < n {
        fr.stalled =
            indeg.iter().enumerate().filter(|&(_, &d)| d > 0).map(|(i, _)| i as u32).collect();
    }

    let fold = program.fold;
    let breakdown = Breakdown::from_intervals(&intervals, makespan);
    (
        RunStats {
            makespan,
            breakdown,
            hbm_bytes,
            flops: program.flops,
            redmule_busy_total: redmule_busy + fold.redmule_busy,
            spatz_busy_total: spatz_busy + fold.spatz_busy,
            ops_executed: executed + fold.ops as usize,
        },
        trace,
        fr,
    )
}

// ---------------------------------------------------------------------------
// Sharded multi-worker execution (§Shard).
// ---------------------------------------------------------------------------

/// Generation barrier: the last arriver resets the count and bumps the
/// generation, releasing spinners. A short spin is followed by
/// `yield_now`, so oversubscribed runs (workers > cores) keep making
/// progress. The release sequence on `count` plus the acquire load of
/// `generation` make every pre-barrier write of every worker visible to
/// every post-barrier read — the only fence the round protocol needs.
struct SpinBarrier {
    threads: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    fn new(threads: usize) -> Self {
        Self { threads, count: AtomicUsize::new(0), generation: AtomicUsize::new(0) }
    }

    fn wait(&self) {
        if self.threads == 1 {
            return;
        }
        let arrived_gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.threads {
            // The reset is ordered before the release store: a freed
            // waiter re-entering `wait` always sees count already reset.
            self.count.store(0, Ordering::Relaxed);
            self.generation.store(arrived_gen.wrapping_add(1), Ordering::Release);
        } else {
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == arrived_gen {
                spins = spins.wrapping_add(1);
                if spins < 128 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Per-owned-shard executor state: the shard's completion-event queue,
/// the FIFO cursors of the resources it owns (dense-indexed via
/// `Program::res_slot`), and the ops released locally this round.
struct ShardRun {
    id: u32,
    queue: EventQueue,
    res_free: Vec<Cycle>,
    ready: Vec<u32>,
}

/// One worker's private accumulators, merged after the join. Counter sums
/// and the interval multiset are order-insensitive; trace records carry a
/// `(round, op id)` tag so the merge reproduces the serial engine's exact
/// emission order.
#[derive(Default)]
struct WorkerOut {
    makespan: Cycle,
    hbm_bytes: u64,
    redmule_busy: Cycle,
    spatz_busy: Cycle,
    executed: usize,
    completed: usize,
    intervals: Vec<(Component, Cycle, Cycle)>,
    trace: Vec<(u64, TraceRecord)>,
    killed: Vec<u32>,
}

/// Schedule one op on its shard's resource cursor — the parallel twin of
/// the serial engine's `schedule!` macro (identical arithmetic, breakdown
/// attribution and fault handling; see there for the issue-time vs
/// start-time rationale and the §Fault determinism argument).
#[allow(clippy::too_many_arguments)]
#[inline]
fn schedule_op(
    program: &Program,
    op_idx: u32,
    now: Cycle,
    round: u64,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
    faults: Option<&ResolvedFaults>,
    sr: &mut ShardRun,
    out: &mut WorkerOut,
) {
    let op = &program.ops()[op_idx as usize];
    if faults.and_then(|f| f.death_of(op.tile)).is_some_and(|at| now >= at) {
        out.killed.push(op_idx);
        return;
    }
    let slot = program.res_slot(op.resource);
    let mut start = sr.res_free[slot].max(now);
    let mut occupancy = op.occupancy;
    if let Some(f) = faults {
        if let Some(ws) = f.outages_of(op.resource.0) {
            for &(from, until) in ws {
                if start >= from && start < until {
                    start = until;
                }
            }
        }
        if let Some(ws) = f.derates_of(op.resource.0) {
            for &(from, until, num, den) in ws {
                if start >= from && start < until {
                    occupancy = occupancy.saturating_mul(num).div_ceil(den);
                    break;
                }
            }
        }
    }
    let released = start + occupancy;
    let complete = released + op.latency;
    sr.res_free[slot] = released;
    sr.queue.push(complete, op_idx);
    match op.component {
        Component::RedMule => out.redmule_busy += occupancy,
        Component::Spatz => out.spatz_busy += occupancy,
        _ => {}
    }
    out.hbm_bytes += op.hbm_bytes;
    if op.tile == tracked_tile && complete > now {
        let from = match op.component {
            Component::HbmAccess
            | Component::Multicast
            | Component::MaxReduce
            | Component::SumReduce => now,
            _ => start,
        };
        out.intervals.push((op.component, from, complete));
    }
    if let Some(limit) = trace_tile_limit {
        if op.tile < limit {
            out.trace.push((round, (op_idx, start, complete)));
        }
    }
    out.executed += 1;
    out.makespan = out.makespan.max(complete);
}

/// One worker's event loop over its statically-owned shards (shard `s` →
/// worker `s % workers`). See [`execute_parallel`] for the round protocol
/// and the exactness argument.
#[allow(clippy::too_many_arguments)]
fn run_worker(
    program: &Program,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
    faults: Option<&ResolvedFaults>,
    w: usize,
    workers: usize,
    indeg: &[AtomicU32],
    inboxes: &[Mutex<Vec<u32>>],
    mins: &[AtomicU64],
    barrier: &SpinBarrier,
) -> WorkerOut {
    let shard_of = program.op_shards();
    let (out_start, out_edges) = program.dependents_csr();
    let mut out = WorkerOut::default();

    let mut shards: Vec<ShardRun> = (w..program.num_shards())
        .step_by(workers)
        .map(|s| ShardRun {
            id: s as u32,
            queue: EventQueue::new(),
            res_free: vec![0; program.shard_res_len(s as u32)],
            ready: Vec::new(),
        })
        .collect();

    // Seed generation (round 0): every zero-indegree op starts at cycle 0,
    // in op-id order within each shard — per resource, exactly the serial
    // seed order (resources never span shards).
    for sr in shards.iter_mut() {
        for &op_idx in program.shard_op_list(sr.id) {
            if program.indeg0[op_idx as usize] == 0 {
                schedule_op(
                    program, op_idx, 0, 0, tracked_tile, trace_tile_limit, faults, sr, &mut out,
                );
            }
        }
    }

    let mut round: u64 = 0;
    loop {
        // Fence 1 — agree on the epoch timestamp: publish this worker's
        // earliest pending completion; after the barrier every worker
        // derives the same global minimum `now`. The publications read
        // here cannot be overwritten early: a worker only republishes
        // after passing fence 2, which in turn waits for this worker.
        let local_min = shards.iter().filter_map(|s| s.queue.next_time()).min().unwrap_or(u64::MAX);
        mins[w].store(local_min, Ordering::Release);
        barrier.wait();
        let now = mins.iter().map(|m| m.load(Ordering::Acquire)).min().unwrap_or(u64::MAX);
        if now == u64::MAX {
            break;
        }
        round += 1;

        // Phase A: drain every owned completion at exactly `now`; settle
        // dependents. A release whose op lives in another shard goes to
        // that shard's inbox (the exactly-once fetch_sub(1) == 1 winner
        // does the push), with ready time `now` implicit.
        for sr in shards.iter_mut() {
            while let Some((t, _)) = sr.queue.peek() {
                if t != now {
                    break;
                }
                let (_, idx) = sr.queue.pop().expect("peeked event exists");
                out.completed += 1;
                let i = idx as usize;
                let (s, e) = (out_start[i] as usize, out_start[i + 1] as usize);
                for &dep_idx in &out_edges[s..e] {
                    let di = dep_idx as usize;
                    if indeg[di].fetch_sub(1, Ordering::AcqRel) == 1 {
                        let target = shard_of[di];
                        if target == sr.id {
                            sr.ready.push(dep_idx);
                        } else {
                            inboxes[target as usize].lock().unwrap().push(dep_idx);
                        }
                    }
                }
            }
        }

        // Fence 2 — every release of this generation has reached its
        // owner's inbox.
        barrier.wait();

        // Phase B: schedule everything released at `now`, op-id order per
        // shard. Resources are shard-private, so this reproduces the
        // serial engine's per-generation op-id batch order on every
        // resource. Zero-duration ops complete at `now` again and form
        // the next generation (the next round re-derives `now` == `now`).
        for sr in shards.iter_mut() {
            {
                let mut inbox = inboxes[sr.id as usize].lock().unwrap();
                sr.ready.append(&mut *inbox);
            }
            if sr.ready.is_empty() {
                continue;
            }
            sr.ready.sort_unstable();
            let ready = std::mem::take(&mut sr.ready);
            for &op_idx in &ready {
                schedule_op(
                    program,
                    op_idx,
                    now,
                    round,
                    tracked_tile,
                    trace_tile_limit,
                    faults,
                    sr,
                    &mut out,
                );
            }
            sr.ready = ready;
            sr.ready.clear();
        }
    }
    out
}

/// Execute `program` with `threads` workers over its §Shard partition —
/// bit-identical to [`execute`] (same `RunStats`, same breakdown, same
/// traces; pinned by `tests/parallel_differential.rs`).
///
/// # Round protocol and why it is exact
///
/// Workers own disjoint shard sets (static round-robin) and advance in
/// *epochs*: every round agrees on the global minimum pending completion
/// time `now` (fence 1), drains all completions at `now` and settles
/// dependents (phase A), then — after fence 2 — schedules every op
/// released at `now` in op-id order per shard (phase B). The serial
/// engine's schedule is fully determined by, per resource, the order of
/// `(ready time, generation, op id)` among its ops; a resource belongs to
/// exactly one shard (`Program::seal` construction), each shard processes
/// its ready stream in exactly that order, and rounds map one-to-one onto
/// the serial engine's same-timestamp generations — so every op gets the
/// identical start cycle and the cross-shard interleaving genuinely
/// commutes. Shards only interact where dependency edges cross the
/// partition, and every such edge has an endpoint in the shared shard's
/// FIFO arbitration; the inbox hand-off at fence 2 delivers those
/// releases within the correct generation.
///
/// Speedup is shape-dependent: rounds synchronize all workers, so the win
/// comes from many shards carrying events at the same timestamp —
/// congruent tile streams (unfolded FlashAttention grids), multi-band
/// scheduler batch programs, per-group FlatAttention chains. Sweeps
/// should prefer point-level fan-out (`coordinator::run_all`), which
/// composes with this executor via `coordinator::set_engine_threads`.
///
/// `threads <= 1`, unsealed programs (no shard map) and single-shard
/// programs take the serial engine directly — same schedule either way.
pub fn execute_parallel(program: &Program, tracked_tile: u32, threads: usize) -> RunStats {
    execute_parallel_traced(program, tracked_tile, None, threads).0
}

/// Traced variant of [`execute_parallel`]; same contract as
/// [`execute_traced`], including the record order.
pub fn execute_parallel_traced(
    program: &Program,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
    threads: usize,
) -> (RunStats, Vec<TraceRecord>) {
    if threads.max(1) == 1 || !program.is_sealed() || program.num_shards() <= 1 {
        return execute_traced(program, tracked_tile, trace_tile_limit);
    }
    let (stats, trace, fr) =
        execute_parallel_core(program, tracked_tile, trace_tile_limit, threads, None);
    if !fr.stalled.is_empty() {
        stall_panic(program, &fr);
    }
    (stats, trace)
}

/// Sharded round-protocol core shared by the fault-free and faulted entry
/// points. Callers guarantee `threads > 1` on a sealed multi-shard
/// program.
fn execute_parallel_core(
    program: &Program,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
    threads: usize,
    faults: Option<&ResolvedFaults>,
) -> (RunStats, Vec<TraceRecord>, FaultReport) {
    let n_shards = program.num_shards();
    let n = program.num_ops();
    let workers = threads.min(n_shards);

    let indeg: Vec<AtomicU32> = program.indeg0.iter().map(|&d| AtomicU32::new(d)).collect();
    let inboxes: Vec<Mutex<Vec<u32>>> = (0..n_shards).map(|_| Mutex::new(Vec::new())).collect();
    let mins: Vec<AtomicU64> = (0..workers).map(|_| AtomicU64::new(u64::MAX)).collect();
    let barrier = SpinBarrier::new(workers);

    let outs: Vec<WorkerOut> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let (indeg, inboxes, mins, barrier) = (&indeg, &inboxes, &mins, &barrier);
                scope.spawn(move || {
                    run_worker(
                        program,
                        tracked_tile,
                        trace_tile_limit,
                        faults,
                        w,
                        workers,
                        indeg,
                        inboxes,
                        mins,
                        barrier,
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("DES worker panicked")).collect()
    });

    // Same accounting as the serial core: scheduled ops all completed;
    // the rest were killed or stalled. Reports sort by op id, so they
    // compare bit-for-bit against the serial engine's.
    let completed: usize = outs.iter().map(|o| o.completed).sum();
    let mut fr = FaultReport::default();
    for o in &outs {
        fr.killed.extend_from_slice(&o.killed);
    }
    fr.killed.sort_unstable();
    if completed + fr.killed.len() < n {
        fr.stalled = indeg
            .iter()
            .enumerate()
            .filter(|(_, d)| d.load(Ordering::Relaxed) > 0)
            .map(|(i, _)| i as u32)
            .collect();
    }

    let mut makespan: Cycle = 0;
    let mut hbm_bytes = 0u64;
    let mut redmule_busy: Cycle = 0;
    let mut spatz_busy: Cycle = 0;
    let mut executed = 0usize;
    let mut intervals: Vec<(Component, Cycle, Cycle)> = Vec::new();
    let mut tagged: Vec<(u64, TraceRecord)> = Vec::new();
    for o in outs {
        makespan = makespan.max(o.makespan);
        hbm_bytes += o.hbm_bytes;
        redmule_busy += o.redmule_busy;
        spatz_busy += o.spatz_busy;
        executed += o.executed;
        intervals.extend_from_slice(&o.intervals);
        tagged.extend_from_slice(&o.trace);
    }
    // Serial record order is (timestamp, generation, op id); rounds
    // enumerate (timestamp, generation) pairs in that exact order.
    tagged.sort_unstable_by_key(|e| (e.0, (e.1).0));
    let trace: Vec<TraceRecord> = tagged.into_iter().map(|(_, r)| r).collect();

    let fold = program.fold;
    let breakdown = Breakdown::from_intervals(&intervals, makespan);
    (
        RunStats {
            makespan,
            breakdown,
            hbm_bytes,
            flops: program.flops,
            redmule_busy_total: redmule_busy + fold.redmule_busy,
            spatz_busy_total: spatz_busy + fold.spatz_busy,
            ops_executed: executed + fold.ops as usize,
        },
        trace,
        fr,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::program::{Op, NO_TILE};

    #[test]
    fn serial_chain_on_one_resource() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(r, 20, 0, Component::RedMule, 0, 0, &[a]);
        let _ = p.op(r, 5, 0, Component::RedMule, 0, 0, &[b]);
        let st = execute(&p, 0);
        assert_eq!(st.makespan, 35);
        assert_eq!(st.breakdown.redmule, 35);
        assert_eq!(st.redmule_busy_total, 35);
    }

    #[test]
    fn independent_ops_on_distinct_resources_overlap() {
        let mut p = Program::new();
        let r1 = p.resource();
        let r2 = p.resource();
        p.op(r1, 100, 0, Component::RedMule, 0, 0, &[]);
        p.op(r2, 60, 0, Component::Spatz, 0, 0, &[]);
        let st = execute(&p, 0);
        // Spatz fully overlapped by RedMulE on the tracked tile.
        assert_eq!(st.makespan, 100);
        assert_eq!(st.breakdown.redmule, 100);
        assert_eq!(st.breakdown.spatz, 0);
    }

    #[test]
    fn resource_contention_serializes() {
        let mut p = Program::new();
        let r = p.resource();
        for _ in 0..4 {
            p.op(r, 25, 0, Component::HbmAccess, 0, 0, &[]);
        }
        let st = execute(&p, 0);
        assert_eq!(st.makespan, 100);
    }

    #[test]
    fn latency_pipelines_but_occupancy_serializes() {
        // Two HBM transfers on one channel: occupancy 10 each, latency 200.
        // Second starts at t=10 (channel free), completes 10+10+200=220.
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 200, Component::HbmAccess, 0, 64, &[]);
        p.op(r, 10, 200, Component::HbmAccess, 0, 64, &[]);
        let st = execute(&p, 0);
        assert_eq!(st.makespan, 220);
        assert_eq!(st.hbm_bytes, 128);
    }

    #[test]
    fn dependency_with_latency() {
        let mut p = Program::new();
        let r1 = p.resource();
        let r2 = p.resource();
        let a = p.op(r1, 10, 50, Component::Multicast, 0, 0, &[]);
        let b = p.op(r2, 5, 0, Component::RedMule, 0, 0, &[a]);
        let st = execute(&p, 0);
        // b starts at a's completion (60), ends 65.
        assert_eq!(st.makespan, 65);
        let _ = b;
    }

    #[test]
    fn fifo_ready_order_is_deterministic() {
        // Three ops become ready at the same time on one resource: executed
        // in op-id order.
        let mut p = Program::new();
        let r0 = p.resource();
        let r = p.resource();
        let gate = p.op(r0, 7, 0, Component::Other, NO_TILE, 0, &[]);
        let a = p.op(r, 10, 0, Component::RedMule, 0, 0, &[gate]);
        let b = p.op(r, 10, 0, Component::Spatz, 0, 0, &[gate]);
        // Downstream op depends on b only; if order were swapped its start
        // would change.
        let c = p.op(r0, 1, 0, Component::Other, NO_TILE, 0, &[b]);
        let st = execute(&p, 0);
        // gate [0,7); a [7,17); b [17,27); c [27,28).
        assert_eq!(st.makespan, 28);
        let _ = (a, c);
    }

    #[test]
    fn barrier_joins_parallel_streams() {
        let mut p = Program::new();
        let rs = p.resources(4);
        let sync = p.resource();
        let mut ids = Vec::new();
        for (i, &r) in rs.iter().enumerate() {
            ids.push(p.op(r, 10 * (i as u64 + 1), 0, Component::RedMule, i as u32, 0, &[]));
        }
        let bar = p.op(sync, 0, 0, Component::Other, NO_TILE, 0, &ids);
        let after = p.op(rs[0], 5, 0, Component::Spatz, 0, 0, &[bar]);
        let st = execute(&p, 0);
        assert_eq!(st.makespan, 45); // slowest stream 40 + 5
        let _ = after;
    }

    #[test]
    fn stats_flops_passthrough() {
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        p.flops = 12345;
        let st = execute(&p, 0);
        assert_eq!(st.flops, 12345);
        assert_eq!(st.ops_executed, 1);
    }

    #[test]
    fn fold_accounting_joins_linear_counters() {
        use crate::sim::program::FoldStats;
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        p.fold = FoldStats { ops: 5, redmule_busy: 100, spatz_busy: 50, streams: 2 };
        let st = execute(&p, 0);
        assert_eq!(st.ops_executed, 6);
        assert_eq!(st.redmule_busy_total, 110);
        assert_eq!(st.spatz_busy_total, 50);
        // The reference engine applies the identical accounting.
        assert_eq!(crate::sim::execute_reference(&p, 0), st);
    }

    #[test]
    fn sealed_and_unsealed_execution_agree() {
        let mut p = Program::new();
        let r = p.resources(3);
        let a = p.op(r[0], 9, 3, Component::HbmAccess, 0, 128, &[]);
        let b = p.op(r[1], 4, 0, Component::RedMule, 0, 0, &[a]);
        let c = p.op(r[2], 6, 1, Component::Spatz, 1, 0, &[a]);
        let _ = p.op(r[0], 2, 0, Component::Other, NO_TILE, 0, &[b, c]);
        let unsealed = execute(&p, 0);
        p.seal();
        let sealed = execute(&p, 0);
        assert_eq!(unsealed, sealed);
    }

    #[test]
    fn parallel_matches_serial_on_small_dags() {
        // Two tile chains contending on one shared channel plus a barrier:
        // exercises seed order, cross-shard releases and the shared
        // shard's FIFO in one sealed DAG.
        let mut p = Program::new();
        let chan = p.resource();
        let engines = p.resources(4);
        let mut last = Vec::new();
        for t in 0..4u32 {
            let load = p.op(chan, 7, 30, Component::HbmAccess, t, 128, &[]);
            let qk = p.op(engines[t as usize], 11 + t as u64, 0, Component::RedMule, t, 0, &[load]);
            let store = p.op(chan, 3, 30, Component::HbmAccess, t, 64, &[qk]);
            last.push(store);
        }
        let sync = p.resource();
        let bar = p.op(sync, 0, 0, Component::Other, NO_TILE, 0, &last);
        let _tail = p.op(engines[0], 5, 0, Component::Spatz, 0, 0, &[bar]);
        p.seal();
        assert!(p.num_shards() >= 2, "shared channel + private chains");
        let (want, want_trace) = execute_traced(&p, 0, Some(u32::MAX));
        for threads in [1, 2, 3, 8] {
            let (got, got_trace) = execute_parallel_traced(&p, 0, Some(u32::MAX), threads);
            assert_eq!(want, got, "threads={threads}");
            assert_eq!(want_trace, got_trace, "threads={threads}");
        }
    }

    #[test]
    fn parallel_falls_back_on_unsealed_and_trivial_programs() {
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        // Unsealed: no shard map — must still execute (serial fallback).
        assert_eq!(execute_parallel(&p, 0, 4), execute(&p, 0));
        p.seal();
        // Single private component (the shared shard is empty): the
        // degenerate two-shard run must still match.
        assert_eq!(p.num_shards(), 2);
        assert_eq!(execute_parallel(&p, 0, 4), execute(&p, 0));
        // Empty program.
        let mut e = Program::new();
        e.seal();
        assert_eq!(execute_parallel(&e, 0, 4), execute(&e, 0));
    }

    #[test]
    fn parallel_same_cycle_cascades_match_serial() {
        // Zero-duration barrier cascades at one timestamp across shards:
        // the generation fences must reproduce the serial batching.
        let mut p = Program::new();
        let chan = p.resource();
        let e0 = p.resource();
        let e1 = p.resource();
        let g = p.op(chan, 5, 0, Component::HbmAccess, 0, 32, &[]);
        let g2 = p.op(chan, 5, 0, Component::HbmAccess, 1, 32, &[]);
        // Both chains release at t=10 through zero-duration links.
        let a0 = p.op(e0, 0, 0, Component::Other, 0, 0, &[g2]);
        let a1 = p.op(e0, 4, 0, Component::Spatz, 0, 0, &[a0]);
        let b0 = p.op(e1, 0, 0, Component::Other, 1, 0, &[g2]);
        let b1 = p.op(e1, 6, 0, Component::RedMule, 1, 0, &[b0]);
        // Joint stores contend on the shared channel at equal ready times.
        let s0 = p.op(chan, 2, 0, Component::HbmAccess, 0, 16, &[a1]);
        let s1 = p.op(chan, 2, 0, Component::HbmAccess, 1, 16, &[b1]);
        let _ = (g, s0, s1);
        p.seal();
        let (want, want_trace) = execute_traced(&p, 1, Some(u32::MAX));
        for threads in [2, 4] {
            let (got, got_trace) = execute_parallel_traced(&p, 1, Some(u32::MAX), threads);
            assert_eq!(want, got, "threads={threads}");
            assert_eq!(want_trace, got_trace, "threads={threads}");
        }
    }

    #[test]
    #[should_panic(expected = "dependency cycle")]
    fn dependency_cycle_panics() {
        // `Program::op` cannot express a cycle (deps must precede the op),
        // so build one manually: op 0 ⇄ op 1.
        let mut p = Program::new();
        let r = p.resource();
        let proto = |deps_start: u32| Op {
            resource: r,
            occupancy: 1,
            latency: 0,
            component: Component::Other,
            tile: NO_TILE,
            hbm_bytes: 0,
            deps_start,
            deps_len: 1,
        };
        p.deps_pool.push(1);
        p.ops.push(proto(0));
        p.deps_pool.push(0);
        p.ops.push(proto(1));
        execute(&p, 0);
    }

    /// Builds the same hand-made 2-op cycle as [`dependency_cycle_panics`].
    fn cyclic_program() -> Program {
        let mut p = Program::new();
        let r = p.resource();
        let proto = |deps_start: u32| Op {
            resource: r,
            occupancy: 1,
            latency: 0,
            component: Component::Other,
            tile: NO_TILE,
            hbm_bytes: 0,
            deps_start,
            deps_len: 1,
        };
        p.deps_pool.push(1);
        p.ops.push(proto(0));
        p.deps_pool.push(0);
        p.ops.push(proto(1));
        p
    }

    #[test]
    fn stall_diagnostics_name_the_stuck_ops() {
        let p = cyclic_program();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| execute(&p, 0)))
            .expect_err("cycle must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .expect("panic payload is a string");
        assert!(msg.contains("dependency cycle"), "{msg}");
        assert!(msg.contains("2 of 2 ops never became ready"), "{msg}");
        // Both cycle members are listed with resource and shard.
        assert!(msg.contains("op 0 (resource 0, shard unsealed"), "{msg}");
        assert!(msg.contains("op 1 (resource 0"), "{msg}");
    }

    #[test]
    fn tile_death_mid_flight_stalls_gracefully() {
        use crate::sim::fault::FaultPlan;
        // a (tile 0) runs [0,10); b (tile 0, dep a) becomes ready at 10 —
        // past the death cycle 5 — and is killed; c (tile 1, dep b) stalls.
        let mut p = Program::new();
        let r0 = p.resource();
        let r1 = p.resource();
        let a = p.op(r0, 10, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(r0, 10, 0, Component::RedMule, 0, 0, &[a]);
        let c = p.op(r1, 5, 0, Component::Spatz, 1, 0, &[b]);
        let plan = FaultPlan::none().with_tile_death(0, 5);
        let (stats, fr) = execute_faulted(&p, 0, &plan, 1);
        assert_eq!(fr.killed, vec![b]);
        assert_eq!(fr.stalled, vec![c]);
        assert_eq!(stats.makespan, 10, "only a ran");
        assert_eq!(stats.ops_executed, 1);
        // Death in the far future is a no-op and the report is clean.
        let late = FaultPlan::none().with_tile_death(0, 1_000);
        let (full, fr2) = execute_faulted(&p, 0, &late, 1);
        assert!(fr2.is_clean());
        assert_eq!(full.makespan, 25);
    }

    #[test]
    fn outage_window_pushes_starts_past_the_window() {
        use crate::sim::fault::FaultPlan;
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 0, Component::HbmAccess, 0, 64, &[]);
        let plan = FaultPlan::none().with_outage(0, 0, 100);
        let (stats, fr) = execute_faulted(&p, 0, &plan, 1);
        assert!(fr.is_clean());
        assert_eq!(stats.makespan, 110, "start pushed to the window end");
        // Back-to-back windows cascade: [0,100) then [100,150).
        let plan2 = FaultPlan::none().with_outage(0, 0, 100).with_outage(0, 100, 150);
        let (stats2, _) = execute_faulted(&p, 0, &plan2, 1);
        assert_eq!(stats2.makespan, 160);
    }

    #[test]
    fn derate_window_multiplies_occupancy_inside_only() {
        use crate::sim::fault::FaultPlan;
        let mut p = Program::new();
        let r = p.resource();
        p.op(r, 10, 0, Component::HbmAccess, 0, 64, &[]);
        p.op(r, 10, 0, Component::HbmAccess, 0, 64, &[]);
        // First op starts at 0 inside the window (10 → 30); the second
        // starts at 30, outside, and keeps its nominal occupancy.
        let plan = FaultPlan::none().with_derate(0, 0, 20, 3, 1);
        let (stats, fr) = execute_faulted(&p, 0, &plan, 1);
        assert!(fr.is_clean());
        assert_eq!(stats.makespan, 40);
        // none() through the faulted entry point is the baseline schedule.
        let (base, fr0) = execute_faulted(&p, 0, &FaultPlan::none(), 1);
        assert!(fr0.is_clean());
        assert_eq!(base, execute(&p, 0));
    }
}
