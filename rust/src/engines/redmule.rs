//! RedMulE matrix-engine timing model.
//!
//! RedMulE [22] is a `rows × cols` array of FP16 compute elements that
//! streams a GEMM in output-stationary passes of `rows × cols` output
//! elements. For an `m × k × n` matmul the engine performs
//! `⌈m/rows⌉·⌈n/cols⌉` passes, each streaming the `k` accumulation depth
//! plus a pipeline fill/drain (`fill`), with a per-invocation offload and
//! configuration overhead (`setup`, issued by the Snitch control core).
//!
//! ```text
//! cycles(m,k,n) = ⌈m/rows⌉ · ⌈n/cols⌉ · (k + fill) + setup
//! ```
//!
//! The two calibration constants reproduce the paper's utilization
//! anchors: a 16×128×16 slice (32×32 group at S = 512) achieves ~23 %
//! utilization when active, while full 128×128×128 slices exceed 85 %
//! (Fig. 4 labels).

use crate::arch::TileConfig;
use crate::sim::Cycle;

/// Cycles for an `m × k × n` FP16 matmul on this tile's RedMulE.
pub fn matmul_cycles(tile: &TileConfig, m: u64, k: u64, n: u64) -> Cycle {
    if m == 0 || k == 0 || n == 0 {
        return 0;
    }
    let passes = m.div_ceil(tile.redmule_rows as u64) * n.div_ceil(tile.redmule_cols as u64);
    passes * (k + tile.redmule_fill) + tile.redmule_setup
}

/// Useful FLOPs of an `m × k × n` matmul (multiply-accumulate = 2 FLOPs).
pub fn matmul_flops(m: u64, k: u64, n: u64) -> u64 {
    2 * m * k * n
}

/// Utilization of the engine while running this matmul.
pub fn matmul_utilization(tile: &TileConfig, m: u64, k: u64, n: u64) -> f64 {
    let cycles = matmul_cycles(tile, m, k, n);
    if cycles == 0 {
        return 0.0;
    }
    matmul_flops(m, k, n) as f64 / (cycles as f64 * tile.redmule_flops_per_cycle() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::table1_tile;

    #[test]
    fn full_slice_high_utilization() {
        let t = table1_tile();
        let u = matmul_utilization(&t, 128, 128, 128);
        assert!(u > 0.85, "128³ utilization {u:.3}");
    }

    #[test]
    fn small_slice_matches_paper_23pct() {
        // Paper §V-B: "in a 32×32 group with a sequence length of 512,
        // every tile's RedMulE achieves only 23% utilization when active."
        // The dominant matmul there is the 16×128×16 QK^T slice.
        let t = table1_tile();
        let u = matmul_utilization(&t, 16, 128, 16);
        assert!(
            (u - 0.23).abs() < 0.04,
            "16×128×16 utilization {u:.3} (paper: ~0.23)"
        );
    }

    #[test]
    fn cycles_monotonic_in_each_dim() {
        let t = table1_tile();
        let base = matmul_cycles(&t, 64, 64, 64);
        assert!(matmul_cycles(&t, 128, 64, 64) >= base);
        assert!(matmul_cycles(&t, 64, 128, 64) >= base);
        assert!(matmul_cycles(&t, 64, 64, 128) >= base);
    }

    #[test]
    fn degenerate_dims_are_free() {
        let t = table1_tile();
        assert_eq!(matmul_cycles(&t, 0, 128, 128), 0);
        assert_eq!(matmul_flops(5, 0, 3), 0);
    }

    #[test]
    fn pass_count_quantization() {
        let t = table1_tile(); // 32×16 array
        // 33 rows needs 2 row passes; 17 cols needs 2 col passes.
        let c1 = matmul_cycles(&t, 32, 100, 16);
        let c2 = matmul_cycles(&t, 33, 100, 16);
        let c3 = matmul_cycles(&t, 32, 100, 17);
        assert_eq!(c2 - t.redmule_setup, 2 * (c1 - t.redmule_setup));
        assert_eq!(c3 - t.redmule_setup, 2 * (c1 - t.redmule_setup));
    }

    #[test]
    fn utilization_bounded() {
        let t = table1_tile();
        for &(m, k, n) in &[(16u64, 16u64, 16u64), (128, 128, 128), (256, 4096, 256), (1, 1, 1)] {
            let u = matmul_utilization(&t, m, k, n);
            assert!((0.0..=1.0).contains(&u), "util {u} for {m}x{k}x{n}");
        }
    }
}
