//! Per-tile engine timing models, calibrated to the paper's Table I specs
//! (see DESIGN.md §6): the RedMulE matrix engine, the Spatz vector engine
//! (with the custom exponential unit of §IV), and the iDMA engine.

pub mod dma;
pub mod redmule;
pub mod spatz;

pub use dma::dma_hbm_time;
pub use redmule::{matmul_cycles, matmul_flops};
pub use spatz::SpatzOp;
