//! iDMA transfer timing for tile↔HBM traffic.
//!
//! An HBM transfer occupies the target HBM channel for `bytes / channel_bw`
//! cycles (the channel is the bottleneck: 64 B/cycle vs 128 B/cycle NoC
//! links and 512 B/cycle L1 ports) and completes after an additional
//! pipeline latency of the HBM access time plus the NoC traversal from the
//! channel's edge attachment to the tile.

use crate::arch::{HbmConfig, NocConfig};
use crate::noc::collective::XferTime;

/// Time for a DMA transfer of `bytes` between a tile and an HBM channel
/// located `hops` routers away.
pub fn dma_hbm_time(hbm: &HbmConfig, noc: &NocConfig, bytes: u64, hops: u64) -> XferTime {
    let bw = hbm
        .channel_bytes_per_cycle
        .min(noc.link_bytes_per_cycle)
        .max(1);
    XferTime {
        occupancy: bytes.div_ceil(bw),
        latency: hbm.access_latency + 2 * noc.inject_latency + hops * noc.router_latency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::table1;

    #[test]
    fn channel_bandwidth_bound() {
        let a = table1();
        let t = dma_hbm_time(&a.hbm, &a.noc, 64 * 1024, 0);
        assert_eq!(t.occupancy, 1024); // 64 KiB at 64 B/cycle
    }

    #[test]
    fn latency_includes_access_and_hops() {
        let a = table1();
        let t = dma_hbm_time(&a.hbm, &a.noc, 64, 10);
        assert_eq!(t.latency, 200 + 20 + 40);
        assert_eq!(t.occupancy, 1);
    }

    #[test]
    fn small_transfer_latency_dominated() {
        // The §V-B over-flattening argument: fixed ~200-cycle HBM access
        // latency dominates small slice transfers.
        let a = table1();
        let t = dma_hbm_time(&a.hbm, &a.noc, 16 * 64 * 2, 0); // 16×64 fp16 slice
        assert!(t.latency > t.occupancy * 5);
    }
}
