//! Spatz vector-engine timing model.
//!
//! Spatz [20] couples compact RVV vector units to the tile; the paper
//! extends it with a custom RVV exponential instruction backed by a
//! dedicated exp unit in the FPU (§IV). Streaming elementwise/reduction
//! ops run at `fpus × lanes` FP16 elements per cycle; exponentials run at
//! `fpus × exp_per_fpu` elements per cycle. Each invocation pays a small
//! fixed issue overhead (vector configuration + offload from the scalar
//! core).

use crate::arch::TileConfig;
use crate::sim::Cycle;

/// Fixed per-invocation overhead (vsetvl + offload), cycles.
pub const SPATZ_ISSUE_OVERHEAD: Cycle = 12;

/// A vector-engine operation over a tile-local slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpatzOp {
    /// Scale `elems` by a scalar (the 1/√D of the attention scores).
    Scale { elems: u64 },
    /// Row-wise max of an `rows × cols` slice (plus running-max merge).
    RowMax { rows: u64, cols: u64 },
    /// Row-wise sum of an `rows × cols` slice.
    RowSum { rows: u64, cols: u64 },
    /// Elementwise `exp(x - m)` over `elems` (custom exp unit).
    Exp { elems: u64 },
    /// Rescale rows by `diag(e^{m_old - m_new})` — `elems` total elements
    /// plus `rows` exponentials for the per-row factors.
    Rescale { rows: u64, elems: u64 },
    /// Final `diag(l)^{-1}` normalization over `elems` with `rows`
    /// reciprocals.
    Normalize { rows: u64, elems: u64 },
    /// Merge running softmax statistics (m, l vectors of `rows` length).
    StatsUpdate { rows: u64 },
}

impl SpatzOp {
    /// Cycles on the given tile.
    pub fn cycles(&self, tile: &TileConfig) -> Cycle {
        let v = tile.spatz_elems_per_cycle().max(1);
        let e = tile.spatz_exp_per_cycle().max(1);
        let body = match *self {
            SpatzOp::Scale { elems } => elems.div_ceil(v),
            SpatzOp::RowMax { rows, cols } => (rows * cols).div_ceil(v) + rows.div_ceil(v),
            SpatzOp::RowSum { rows, cols } => (rows * cols).div_ceil(v) + rows.div_ceil(v),
            SpatzOp::Exp { elems } => elems.div_ceil(e),
            SpatzOp::Rescale { rows, elems } => rows.div_ceil(e) + elems.div_ceil(v),
            SpatzOp::Normalize { rows, elems } => {
                // Reciprocal via the FPU divider: ~4 elems/FPU/cycle.
                rows.div_ceil((tile.spatz_fpus as u64 * 4).max(1)) + elems.div_ceil(v)
            }
            SpatzOp::StatsUpdate { rows } => 2 * rows.div_ceil(v) + rows.div_ceil(e),
        };
        body + SPATZ_ISSUE_OVERHEAD
    }

    /// Useful FLOPs for utilization accounting (1 per element op).
    pub fn flops(&self) -> u64 {
        match *self {
            SpatzOp::Scale { elems } => elems,
            SpatzOp::RowMax { rows, cols } | SpatzOp::RowSum { rows, cols } => rows * cols,
            SpatzOp::Exp { elems } => elems,
            SpatzOp::Rescale { rows, elems } => rows + elems,
            SpatzOp::Normalize { rows, elems } => rows + elems,
            SpatzOp::StatsUpdate { rows } => 3 * rows,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::table1_tile;

    #[test]
    fn table1_throughputs() {
        let t = table1_tile();
        assert_eq!(t.spatz_elems_per_cycle(), 128);
        assert_eq!(t.spatz_exp_per_cycle(), 16);
    }

    #[test]
    fn exp_dominates_softmax_cost() {
        // 128×128 slice: exp is the expensive part (16/cycle vs 128/cycle).
        let t = table1_tile();
        let exp = SpatzOp::Exp { elems: 128 * 128 }.cycles(&t);
        let rowmax = SpatzOp::RowMax { rows: 128, cols: 128 }.cycles(&t);
        assert!(exp > 3 * rowmax, "exp={exp} rowmax={rowmax}");
        // 16384 exps at 16/cycle = 1024 + overhead.
        assert_eq!(exp, 1024 + SPATZ_ISSUE_OVERHEAD);
    }

    #[test]
    fn issue_overhead_floors_small_ops() {
        let t = table1_tile();
        let c = SpatzOp::StatsUpdate { rows: 4 }.cycles(&t);
        assert!(c >= SPATZ_ISSUE_OVERHEAD);
    }

    #[test]
    fn cycles_scale_linearly_in_elems() {
        let t = table1_tile();
        let c1 = SpatzOp::Scale { elems: 1280 }.cycles(&t) - SPATZ_ISSUE_OVERHEAD;
        let c2 = SpatzOp::Scale { elems: 2560 }.cycles(&t) - SPATZ_ISSUE_OVERHEAD;
        assert_eq!(c2, 2 * c1);
    }

    #[test]
    fn flops_accounting() {
        assert_eq!(SpatzOp::Exp { elems: 100 }.flops(), 100);
        assert_eq!(SpatzOp::RowMax { rows: 4, cols: 8 }.flops(), 32);
    }
}
