//! Canonical architecture instances from the paper.

use super::config::{ArchConfig, HbmConfig, NocConfig, TileConfig};

/// RedMulE timing-calibration constants (DESIGN.md §6): pipeline fill per
/// output-tile pass and per-invocation offload/setup overhead. Calibrated
/// so a 16×128×16 slice lands near the paper's reported 23% active
/// utilization (32×32 group, S=512) while 128×128×128 blocks exceed 85%.
pub const REDMULE_FILL: u64 = 8;
/// Per-invocation RedMulE offload/setup overhead in cycles (see [`REDMULE_FILL`]).
pub const REDMULE_SETUP: u64 = 120;

/// Table I tile: RedMulE 32×16 CE (1 TFLOPS @ FP16), Spatz 16 FPU
/// (128 GFLOPS), 384 KiB L1 at 512 GB/s.
pub fn table1_tile() -> TileConfig {
    TileConfig {
        redmule_rows: 32,
        redmule_cols: 16,
        redmule_fill: REDMULE_FILL,
        redmule_setup: REDMULE_SETUP,
        spatz_fpus: 16,
        spatz_lanes_per_fpu: 8,
        spatz_exp_per_fpu: 1,
        l1_kib: 384,
        l1_bytes_per_cycle: 512,
    }
}

/// Table I system: 32×32 tiles, 1024-bit NoC links, 16×2 HBM channels
/// split over the west and south edges, hardware collectives available.
pub fn table1() -> ArchConfig {
    ArchConfig {
        name: "table1-32x32".into(),
        mesh_x: 32,
        mesh_y: 32,
        tile: table1_tile(),
        noc: NocConfig {
            link_bytes_per_cycle: 128, // 1024-bit
            router_latency: 4,         // Lr (§II example)
            inject_latency: 10,        // Ld (§II example)
            hw_collectives: true,
        },
        hbm: HbmConfig {
            channels_west: 16,
            channels_south: 16,
            channel_bytes_per_cycle: 64, // HBM2e 64 GB/s per channel
            access_latency: 200,         // §V-B
        },
        freq_ghz: 1.0,
    }
}

/// The same system with hardware collective support disabled (software
/// point-to-point collectives) — the `Flat` baseline of Fig. 3.
pub fn table1_sw_collectives() -> ArchConfig {
    let mut a = table1();
    a.name = "table1-32x32-swcoll".into();
    a.noc.hw_collectives = false;
    a
}

/// Table II: iso-peak-performance (1024 TFLOPS) and iso-on-chip-memory
/// configurations at different fabric granularities.
///
/// | granularity | RedMulE CE | Spatz FU | L1 (KiB) | L1 BW (GB/s) |
/// |-------------|-----------|----------|----------|--------------|
/// | 32×32       | 32×16     | 16       | 386*     | 512          |
/// | 16×16       | 64×32     | 64       | 1536     | 2048         |
/// | 8×8         | 128×64    | 256      | 6144     | 8192         |
///
/// *Table II prints 386/1526 KB; we use 384/1536 (the consistent
/// power-of-two scaling of the 32×32 baseline — the printed values are
/// evidently typos, as 4·384 = 1536 and 4·1536 = 6144).
pub fn table2(granularity: usize) -> ArchConfig {
    let (mesh, ce_rows, ce_cols, fpus, l1_kib, l1_bw) = match granularity {
        32 => (32, 32, 16, 16, 384, 512),
        16 => (16, 64, 32, 64, 1536, 2048),
        8 => (8, 128, 64, 256, 6144, 8192),
        g => panic!("Table II defines granularities 32/16/8, not {g}"),
    };
    let mut a = table1();
    a.name = format!("table2-{mesh}x{mesh}");
    a.mesh_x = mesh;
    a.mesh_y = mesh;
    a.tile = TileConfig {
        redmule_rows: ce_rows,
        redmule_cols: ce_cols,
        redmule_fill: REDMULE_FILL * (ce_cols as u64 / 16),
        redmule_setup: REDMULE_SETUP,
        spatz_fpus: fpus,
        spatz_lanes_per_fpu: 8,
        spatz_exp_per_fpu: 1,
        l1_kib,
        l1_bytes_per_cycle: l1_bw,
    };
    // HBM channels are capped by edge length (≤ mesh rows/cols per edge).
    a.hbm.channels_west = a.hbm.channels_west.min(mesh);
    a.hbm.channels_south = a.hbm.channels_south.min(mesh);
    a
}

/// A Table-II architecture with an explicit HBM channel configuration
/// (`channels_per_edge` west + the same south) for the Fig. 5a
/// co-exploration heatmap.
pub fn with_hbm_channels(mut a: ArchConfig, channels_per_edge: usize) -> ArchConfig {
    assert!(channels_per_edge >= 1);
    let per_edge = channels_per_edge.min(a.mesh_y);
    a.hbm.channels_west = per_edge;
    a.hbm.channels_south = channels_per_edge.min(a.mesh_x);
    a.name = format!("{}-hbm{}x2", a.name, channels_per_edge);
    a
}

/// The paper's selected optimum (§V-C): 32×32 fabric granularity with
/// 16×2 HBM channels — identical to Table I with hardware collectives.
pub fn best_arch() -> ArchConfig {
    let mut a = table1();
    a.name = "BestArch".into();
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_is_iso_peak_and_iso_memory() {
        let base = table2(32);
        for g in [16usize, 8] {
            let a = table2(g);
            assert_eq!(
                a.peak_flops_per_cycle(),
                base.peak_flops_per_cycle(),
                "granularity {g} must match peak"
            );
            assert_eq!(
                a.total_l1_bytes(),
                base.total_l1_bytes(),
                "granularity {g} must match total L1"
            );
            assert!(a.validate().is_empty(), "{:?}", a.validate());
        }
    }

    #[test]
    fn table2_tile_specs_match_paper() {
        let a = table2(16);
        assert_eq!((a.tile.redmule_rows, a.tile.redmule_cols), (64, 32));
        assert_eq!(a.tile.spatz_fpus, 64);
        assert_eq!(a.tile.l1_bytes_per_cycle, 2048);
        let b = table2(8);
        assert_eq!((b.tile.redmule_rows, b.tile.redmule_cols), (128, 64));
        assert_eq!(b.tile.spatz_fpus, 256);
        assert_eq!(b.tile.l1_kib, 6144);
    }

    #[test]
    #[should_panic(expected = "Table II")]
    fn table2_rejects_unknown_granularity() {
        table2(12);
    }

    #[test]
    fn hbm_channel_override() {
        let a = with_hbm_channels(table2(8), 16);
        // 8×8 mesh can host at most 8 channels per edge.
        assert_eq!(a.hbm.channels_west, 8);
        let b = with_hbm_channels(table2(32), 8);
        assert_eq!(b.hbm.channels_west, 8);
        assert_eq!(b.hbm.channels_south, 8);
    }

    #[test]
    fn best_arch_is_table1_shape() {
        let a = best_arch();
        assert_eq!(a.num_tiles(), 1024);
        assert!(a.noc.hw_collectives);
        assert_eq!(a.hbm.total_channels(), 32);
    }
}
