//! Architecture configuration types and validation.

use crate::util::json::Json;

/// Per-tile compute and memory resources (paper Table I / Table II).
#[derive(Debug, Clone, PartialEq)]
pub struct TileConfig {
    /// RedMulE compute-element array rows (the `32` of a 32×16 array).
    pub redmule_rows: usize,
    /// RedMulE compute-element array columns.
    pub redmule_cols: usize,
    /// RedMulE pipeline fill/drain overhead per output-tile pass (cycles).
    /// Calibration constant; see DESIGN.md §6.
    pub redmule_fill: u64,
    /// RedMulE per-invocation offload/configuration overhead (cycles).
    pub redmule_setup: u64,
    /// Spatz FPU count (Table I: 16).
    pub spatz_fpus: usize,
    /// FP16 elements processed per FPU per cycle for streaming vector ops
    /// (mul/add/max/sum). 8 lanes ⇒ 16 FPUs × 8 = 128 elem/cycle =
    /// 128 GFLOPS @ 1 GHz as in Table I.
    pub spatz_lanes_per_fpu: usize,
    /// Exponentials per FPU per cycle via the custom RVV exp unit (§IV).
    /// `0` models the *ablated* configuration without the exp unit: a
    /// software polynomial at ~16 vector FLOPs per exponential.
    pub spatz_exp_per_fpu: usize,
    /// L1 scratchpad size in KiB.
    pub l1_kib: usize,
    /// L1 bandwidth in bytes/cycle (Table I: 512 GB/s @ 1 GHz).
    pub l1_bytes_per_cycle: u64,
}

impl TileConfig {
    /// Peak FLOP/cycle of the matrix engine (FMA = 2 FLOPs per CE).
    pub fn redmule_flops_per_cycle(&self) -> u64 {
        2 * (self.redmule_rows * self.redmule_cols) as u64
    }

    /// Peak FLOP/cycle of the vector engine.
    pub fn spatz_flops_per_cycle(&self) -> u64 {
        (self.spatz_fpus * self.spatz_lanes_per_fpu) as u64
    }

    /// Streaming vector elements per cycle.
    pub fn spatz_elems_per_cycle(&self) -> u64 {
        (self.spatz_fpus * self.spatz_lanes_per_fpu) as u64
    }

    /// Exponential evaluations per cycle. With the custom exp unit (§IV):
    /// one per FPU per cycle (× `spatz_exp_per_fpu`); without it
    /// (`spatz_exp_per_fpu == 0`): software polynomial at 16 vector FLOPs
    /// per exponential.
    pub fn spatz_exp_per_cycle(&self) -> u64 {
        if self.spatz_exp_per_fpu == 0 {
            (self.spatz_elems_per_cycle() / 16).max(1)
        } else {
            (self.spatz_fpus * self.spatz_exp_per_fpu) as u64
        }
    }

    /// Tile L1 capacity in bytes.
    pub fn l1_bytes(&self) -> u64 {
        self.l1_kib as u64 * 1024
    }
}

/// On-chip mesh fabric parameters (§II latency model).
#[derive(Debug, Clone, PartialEq)]
pub struct NocConfig {
    /// Router link width in bytes/cycle (Table I: 1024-bit = 128 B/cycle).
    pub link_bytes_per_cycle: u64,
    /// Router-to-router hop latency `Lr` (cycles).
    pub router_latency: u64,
    /// L1-to-NoC injection/ejection latency `Ld` (cycles).
    pub inject_latency: u64,
    /// Hardware collective support (path-based in-flight forwarding for
    /// multicast and in-network reduction). When false, collectives fall
    /// back to successive point-to-point unicasts (§II).
    pub hw_collectives: bool,
}

/// Main-memory (HBM) configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct HbmConfig {
    /// Channels attached along the west edge (serve Q/O row traffic).
    pub channels_west: usize,
    /// Channels attached along the south edge (serve K/V column traffic).
    pub channels_south: usize,
    /// Per-channel bandwidth in bytes/cycle (HBM2e: 64 GB/s @ 1 GHz).
    pub channel_bytes_per_cycle: u64,
    /// Access latency in cycles (paper §V-B: ~200).
    pub access_latency: u64,
}

impl HbmConfig {
    /// West + south channel count.
    pub fn total_channels(&self) -> usize {
        self.channels_west + self.channels_south
    }

    /// Aggregate peak bandwidth in bytes/cycle.
    pub fn peak_bytes_per_cycle(&self) -> u64 {
        self.total_channels() as u64 * self.channel_bytes_per_cycle
    }

    /// Aggregate peak bandwidth in GB/s at the given clock.
    pub fn peak_gbps(&self, freq_ghz: f64) -> f64 {
        self.peak_bytes_per_cycle() as f64 * freq_ghz
    }
}

/// A full accelerator instance.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Preset name (reports and JSON).
    pub name: String,
    /// Mesh width (tiles in x).
    pub mesh_x: usize,
    /// Mesh height (tiles in y).
    pub mesh_y: usize,
    /// Per-tile compute/memory configuration.
    pub tile: TileConfig,
    /// Mesh NoC configuration.
    pub noc: NocConfig,
    /// HBM channel configuration.
    pub hbm: HbmConfig,
    /// Clock frequency (paper: 1 GHz).
    pub freq_ghz: f64,
}

impl ArchConfig {
    /// Total tiles in the mesh.
    pub fn num_tiles(&self) -> usize {
        self.mesh_x * self.mesh_y
    }

    /// Whole-system peak FLOP/cycle (matrix engines only, as in the paper's
    /// peak-performance accounting).
    pub fn peak_flops_per_cycle(&self) -> u64 {
        self.num_tiles() as u64 * self.tile.redmule_flops_per_cycle()
    }

    /// Peak performance in TFLOPS.
    pub fn peak_tflops(&self) -> f64 {
        self.peak_flops_per_cycle() as f64 * self.freq_ghz / 1e3
    }

    /// Total on-chip L1 in bytes.
    pub fn total_l1_bytes(&self) -> u64 {
        self.num_tiles() as u64 * self.tile.l1_bytes()
    }

    /// Flat tile id for mesh coordinates.
    pub fn tile_id(&self, x: usize, y: usize) -> u32 {
        debug_assert!(x < self.mesh_x && y < self.mesh_y);
        (y * self.mesh_x + x) as u32
    }

    /// Check internal consistency; returns a list of problems (empty = ok).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        if self.mesh_x == 0 || self.mesh_y == 0 {
            problems.push("mesh dimensions must be positive".into());
        }
        if self.tile.redmule_rows == 0 || self.tile.redmule_cols == 0 {
            problems.push("RedMulE array must be non-empty".into());
        }
        if self.tile.spatz_fpus == 0 {
            problems.push("Spatz must have at least one FPU".into());
        }
        if self.tile.l1_kib < 16 {
            problems.push(format!("L1 of {} KiB is too small to hold any block", self.tile.l1_kib));
        }
        if self.noc.link_bytes_per_cycle == 0 {
            problems.push("NoC link bandwidth must be positive".into());
        }
        if self.hbm.total_channels() == 0 {
            problems.push("need at least one HBM channel".into());
        }
        if self.hbm.channels_west > self.mesh_y {
            problems.push(format!(
                "{} west HBM channels exceed {} mesh rows",
                self.hbm.channels_west, self.mesh_y
            ));
        }
        if self.hbm.channels_south > self.mesh_x {
            problems.push(format!(
                "{} south HBM channels exceed {} mesh columns",
                self.hbm.channels_south, self.mesh_x
            ));
        }
        if self.freq_ghz <= 0.0 {
            problems.push("clock frequency must be positive".into());
        }
        problems
    }

    /// Serialize for result stores and reports.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::str(self.name.clone())),
            ("mesh", Json::Arr(vec![Json::num(self.mesh_x as f64), Json::num(self.mesh_y as f64)])),
            ("peak_tflops", Json::num(self.peak_tflops())),
            ("hbm_channels", Json::num(self.hbm.total_channels() as f64)),
            ("hbm_peak_gbps", Json::num(self.hbm.peak_gbps(self.freq_ghz))),
            ("l1_kib_per_tile", Json::num(self.tile.l1_kib as f64)),
            ("hw_collectives", Json::Bool(self.noc.hw_collectives)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::super::presets;

    #[test]
    fn table1_peaks_match_paper() {
        let a = presets::table1();
        // Table I summary: 1024 TFLOPS peak, 2 TB/s peak HBM bandwidth.
        assert_eq!(a.num_tiles(), 1024);
        assert_eq!(a.tile.redmule_flops_per_cycle(), 1024); // 1 TFLOPS @ 1 GHz
        assert!((a.peak_tflops() - 1048.576).abs() < 1e-6); // 2*32*16*1024 FLOP/cyc
        assert_eq!(a.hbm.total_channels(), 32);
        assert!((a.hbm.peak_gbps(a.freq_ghz) - 2048.0).abs() < 1e-6);
        assert_eq!(a.tile.spatz_flops_per_cycle(), 128); // 128 GFLOPS @ 1 GHz
        assert!(a.validate().is_empty());
    }

    #[test]
    fn tile_id_row_major() {
        let a = presets::table1();
        assert_eq!(a.tile_id(0, 0), 0);
        assert_eq!(a.tile_id(1, 0), 1);
        assert_eq!(a.tile_id(0, 1), 32);
    }

    #[test]
    fn validate_flags_bad_configs() {
        let mut a = presets::table1();
        a.mesh_x = 0;
        assert!(!a.validate().is_empty());

        let mut b = presets::table1();
        b.hbm.channels_west = 64; // more channels than rows
        assert!(!b.validate().is_empty());
    }
}
