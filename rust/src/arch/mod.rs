//! Architecture configuration for tile-based many-PE accelerators.
//!
//! Mirrors the paper's §II reference template: a 2-D mesh of tiles, each
//! with a RedMulE matrix engine, a Spatz vector engine, an iDMA engine and
//! a local L1 scratchpad, connected by a FlooNoC-style mesh with optional
//! hardware collective support, with HBM channels at the west and south
//! mesh edges.

pub mod area;
pub mod config;
pub mod loader;
pub mod presets;

pub use area::{AreaModel, DieArea};
pub use loader::{load_arch, parse_arch};
pub use config::{ArchConfig, HbmConfig, NocConfig, TileConfig};
