//! Architecture configuration files (`configs/*.toml`).
//!
//! Every field defaults to the Table I value, so a config file only states
//! its deviations — e.g. a 16×16 fabric study only sets `[mesh]` and
//! `[tile]`. See `configs/table1.toml` for the fully-spelled-out baseline.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::toml::parse_toml;

use super::config::ArchConfig;
use super::presets;

/// Load an [`ArchConfig`] from a TOML file (Table I defaults).
pub fn load_arch(path: &Path) -> Result<ArchConfig> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let arch = parse_arch(&text, path.file_stem().and_then(|s| s.to_str()).unwrap_or("custom"))
        .map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let problems = arch.validate();
    if !problems.is_empty() {
        return Err(anyhow!("{}: invalid config: {}", path.display(), problems.join("; ")));
    }
    Ok(arch)
}

/// Parse from a TOML string (defaults from Table I).
pub fn parse_arch(text: &str, default_name: &str) -> Result<ArchConfig, String> {
    let doc = parse_toml(text)?;
    let mut a = presets::table1();
    a.name = doc
        .get("", "name")
        .and_then(|v| v.as_str())
        .unwrap_or(default_name)
        .to_string();
    a.freq_ghz = doc.f64_or("", "freq_ghz", a.freq_ghz);

    a.mesh_x = doc.usize_or("mesh", "x", a.mesh_x);
    a.mesh_y = doc.usize_or("mesh", "y", a.mesh_y);

    a.tile.redmule_rows = doc.usize_or("tile", "redmule_rows", a.tile.redmule_rows);
    a.tile.redmule_cols = doc.usize_or("tile", "redmule_cols", a.tile.redmule_cols);
    a.tile.redmule_fill = doc.u64_or("tile", "redmule_fill", a.tile.redmule_fill);
    a.tile.redmule_setup = doc.u64_or("tile", "redmule_setup", a.tile.redmule_setup);
    a.tile.spatz_fpus = doc.usize_or("tile", "spatz_fpus", a.tile.spatz_fpus);
    a.tile.spatz_lanes_per_fpu = doc.usize_or("tile", "spatz_lanes_per_fpu", a.tile.spatz_lanes_per_fpu);
    a.tile.spatz_exp_per_fpu = doc.usize_or("tile", "spatz_exp_per_fpu", a.tile.spatz_exp_per_fpu);
    a.tile.l1_kib = doc.usize_or("tile", "l1_kib", a.tile.l1_kib);
    a.tile.l1_bytes_per_cycle = doc.u64_or("tile", "l1_bytes_per_cycle", a.tile.l1_bytes_per_cycle);

    a.noc.link_bytes_per_cycle = doc.u64_or("noc", "link_bytes_per_cycle", a.noc.link_bytes_per_cycle);
    a.noc.router_latency = doc.u64_or("noc", "router_latency", a.noc.router_latency);
    a.noc.inject_latency = doc.u64_or("noc", "inject_latency", a.noc.inject_latency);
    a.noc.hw_collectives = doc.bool_or("noc", "hw_collectives", a.noc.hw_collectives);

    a.hbm.channels_west = doc.usize_or("hbm", "channels_west", a.hbm.channels_west);
    a.hbm.channels_south = doc.usize_or("hbm", "channels_south", a.hbm.channels_south);
    a.hbm.channel_bytes_per_cycle =
        doc.u64_or("hbm", "channel_bytes_per_cycle", a.hbm.channel_bytes_per_cycle);
    a.hbm.access_latency = doc.u64_or("hbm", "access_latency", a.hbm.access_latency);

    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_config_is_table1() {
        let a = parse_arch("", "x").unwrap();
        let t1 = presets::table1();
        assert_eq!(a.mesh_x, t1.mesh_x);
        assert_eq!(a.tile, t1.tile);
        assert_eq!(a.hbm, t1.hbm);
    }

    #[test]
    fn overrides_apply() {
        let a = parse_arch(
            "name = \"mini\"\n[mesh]\nx = 8\ny = 8\n[tile]\nl1_kib = 6144\n[noc]\nhw_collectives = false\n[hbm]\nchannels_west = 8\nchannels_south = 8\n",
            "x",
        )
        .unwrap();
        assert_eq!(a.name, "mini");
        assert_eq!((a.mesh_x, a.mesh_y), (8, 8));
        assert_eq!(a.tile.l1_kib, 6144);
        assert!(!a.noc.hw_collectives);
        assert_eq!(a.hbm.channels_west, 8);
    }

    #[test]
    fn load_validates() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("fa-arch-{}.toml", std::process::id()));
        std::fs::write(&path, "[mesh]\nx = 0\n").unwrap();
        assert!(load_arch(&path).is_err());
        std::fs::write(&path, "[mesh]\nx = 16\ny = 16\n[hbm]\nchannels_west = 16\nchannels_south = 16\n").unwrap();
        let a = load_arch(&path).unwrap();
        assert_eq!(a.num_tiles(), 256);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shipped_configs_parse() {
        // Validate every file in configs/ if present (repo root).
        let dir = std::path::Path::new("configs");
        if dir.is_dir() {
            for entry in std::fs::read_dir(dir).unwrap() {
                let p = entry.unwrap().path();
                if p.extension().is_some_and(|e| e == "toml") {
                    load_arch(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()));
                }
            }
        }
    }
}
