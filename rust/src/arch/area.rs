//! Die-area estimation (§V-C).
//!
//! The paper estimates BestArch's die size from the gate-equivalent (GE)
//! counts reported for the open-source components (Snitch [19], Spatz [20],
//! iDMA [21], RedMulE [22], FlooNoC [23]) mapped onto TSMC 5 nm with the
//! constants it states: 4 transistors/GE, 138.2 MTr/mm² logic density,
//! 0.021 µm² SRAM bit-cell, 66 % area utilization — arriving at 457 mm²
//! vs. the H100's 814 mm² (1.8× smaller).
//!
//! The per-component GE figures below are taken from those publications
//! (RedMulE ~9.5 kGE/CE including its accumulation/datapath share, Spatz
//! ~120 kGE per FPU lane-group, Snitch ~25 kGE/core, iDMA ~150 kGE, a wide
//! FlooNoC router with collective support ~600 kGE, plus tile interconnect
//! and control ~250 kGE).

use super::config::ArchConfig;

/// TSMC 5 nm process constants from §V-C.
#[derive(Debug, Clone)]
pub struct ProcessNode {
    /// Transistors per gate equivalent.
    pub transistors_per_ge: f64,
    /// Logic transistor density in MTr/mm².
    pub mtr_per_mm2: f64,
    /// SRAM bit-cell area in µm².
    pub sram_um2_per_bit: f64,
    /// Achievable area utilization.
    pub utilization: f64,
}

impl ProcessNode {
    /// The process point used by the paper's area estimate.
    pub fn tsmc_5nm() -> Self {
        Self {
            transistors_per_ge: 4.0,
            mtr_per_mm2: 138.2,
            sram_um2_per_bit: 0.021,
            utilization: 0.66,
        }
    }
}

/// Per-component gate-equivalent model.
#[derive(Debug, Clone)]
pub struct AreaModel {
    /// GE per RedMulE compute element (datapath + accumulation share).
    pub ge_per_redmule_ce: f64,
    /// GE per Spatz FPU (including its vector lanes and sequencer share).
    pub ge_per_spatz_fpu: f64,
    /// Scalar (Snitch) cores per tile and GE per core.
    pub snitch_cores_per_tile: f64,
    /// GE per Snitch scalar core.
    pub ge_per_snitch: f64,
    /// GE for the iDMA engine.
    pub ge_idma: f64,
    /// GE for the NoC router (wide links + collective datapath).
    pub ge_router: f64,
    /// GE for tile-local interconnect, control, and instruction cache logic.
    pub ge_tile_misc: f64,
    /// Process node the GE counts are converted with.
    pub process: ProcessNode,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self {
            ge_per_redmule_ce: 9_500.0,
            ge_per_spatz_fpu: 120_000.0,
            snitch_cores_per_tile: 4.0,
            ge_per_snitch: 25_000.0,
            ge_idma: 150_000.0,
            ge_router: 600_000.0,
            ge_tile_misc: 250_000.0,
            process: ProcessNode::tsmc_5nm(),
        }
    }
}

/// Die-area estimate decomposition (mm²).
#[derive(Debug, Clone)]
pub struct DieArea {
    /// Logic area (GE-derived).
    pub logic_mm2: f64,
    /// SRAM macro area.
    pub sram_mm2: f64,
    /// Total including the utilization factor.
    pub total_mm2: f64,
    /// Total logic gate-equivalents.
    pub total_ge: f64,
}

/// H100 die size (mm²) on the same node, for the paper's 1.8× comparison.
pub const H100_DIE_MM2: f64 = 814.0;

impl AreaModel {
    /// GE count of one tile's logic.
    pub fn tile_ge(&self, arch: &ArchConfig) -> f64 {
        let ces = (arch.tile.redmule_rows * arch.tile.redmule_cols) as f64;
        ces * self.ge_per_redmule_ce
            + arch.tile.spatz_fpus as f64 * self.ge_per_spatz_fpu
            + self.snitch_cores_per_tile * self.ge_per_snitch
            + self.ge_idma
            + self.ge_router
            + self.ge_tile_misc
    }

    /// Estimate the die area of an architecture.
    pub fn estimate(&self, arch: &ArchConfig) -> DieArea {
        let total_ge = self.tile_ge(arch) * arch.num_tiles() as f64;
        let logic_mm2 = total_ge * self.process.transistors_per_ge / (self.process.mtr_per_mm2 * 1e6);
        let sram_bits = arch.total_l1_bytes() as f64 * 8.0;
        let sram_mm2 = sram_bits * self.process.sram_um2_per_bit * 1e-6;
        let total_mm2 = (logic_mm2 + sram_mm2) / self.process.utilization;
        DieArea {
            logic_mm2,
            sram_mm2,
            total_mm2,
            total_ge,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn best_arch_lands_near_457mm2() {
        let model = AreaModel::default();
        let area = model.estimate(&presets::best_arch());
        assert!(
            (area.total_mm2 - 457.0).abs() < 15.0,
            "BestArch estimated at {:.1} mm², paper reports 457 mm²",
            area.total_mm2
        );
    }

    #[test]
    fn reduction_vs_h100_near_1_8x() {
        let model = AreaModel::default();
        let area = model.estimate(&presets::best_arch());
        let ratio = H100_DIE_MM2 / area.total_mm2;
        assert!(
            (ratio - 1.8).abs() < 0.1,
            "area reduction {ratio:.2}× (paper: 1.8×)"
        );
    }

    #[test]
    fn sram_area_scales_with_l1() {
        let model = AreaModel::default();
        let a32 = model.estimate(&presets::table2(32));
        let a8 = model.estimate(&presets::table2(8));
        // Iso-memory configurations: SRAM area identical.
        assert!((a32.sram_mm2 - a8.sram_mm2).abs() < 1e-9);
    }

    #[test]
    fn coarser_fabric_has_fewer_routers() {
        // 8×8 has 64 routers vs 1024 — router+misc overhead shrinks, CE
        // count is constant, so total GE must be smaller.
        let model = AreaModel::default();
        let ge32 = model.estimate(&presets::table2(32)).total_ge;
        let ge8 = model.estimate(&presets::table2(8)).total_ge;
        assert!(ge8 < ge32);
    }
}
