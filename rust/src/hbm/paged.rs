//! Page-granular KV-cache → HBM-channel mapping.
//!
//! A serving KV cache grows token by token, so production systems
//! (vLLM-style paged attention) allocate it in fixed-size *pages* and the
//! physical placement of those pages decides which memory channel each
//! attention K/V read hits. This module is the mechanism half: a
//! [`PageMap`] records, per fixed-size token page, the HBM channel that
//! holds it, and splits an arbitrary token range into per-channel
//! transfer segments. The *policy* half (round-robin / channel-affine /
//! random placement) lives in `crate::scheduler`, which owns the
//! allocation order; the dataflow builders consume the map so paged
//! fragmentation shows up as real channel contention in the DES rather
//! than as an analytic penalty.

/// Channel placement of one request's KV cache at fixed page granularity.
///
/// Pages are `page_tokens` KV positions each; a page holds both the K and
/// the V vectors of its tokens (2·D FP16 elements per token). The table
/// only grows — tokens are appended as the request prefills/decodes and
/// pages are never migrated, which is exactly what makes fragmented
/// placements persistent.
#[derive(Debug, Clone)]
pub struct PageMap {
    page_tokens: u64,
    channels: Vec<u32>,
}

impl PageMap {
    /// An empty map with the given page size (tokens).
    pub fn new(page_tokens: u64) -> Self {
        assert!(page_tokens > 0, "page size must be >= 1 token");
        Self { page_tokens, channels: Vec::new() }
    }

    /// Page size in tokens.
    pub fn page_tokens(&self) -> u64 {
        self.page_tokens
    }

    /// Pages currently mapped.
    pub fn num_pages(&self) -> usize {
        self.channels.len()
    }

    /// Pages needed to hold `tokens` KV positions.
    pub fn pages_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.page_tokens)
    }

    /// Tokens currently covered by allocated pages.
    pub fn tokens_capacity(&self) -> u64 {
        self.channels.len() as u64 * self.page_tokens
    }

    /// Grow the table until it covers `tokens` positions, asking `alloc`
    /// for the channel of each newly allocated page (by global page
    /// index, in order). Never shrinks or moves existing pages.
    pub fn grow_to(&mut self, tokens: u64, mut alloc: impl FnMut(u64) -> u32) {
        let need = self.pages_for(tokens);
        while (self.channels.len() as u64) < need {
            let page = self.channels.len() as u64;
            let chan = alloc(page);
            self.channels.push(chan);
        }
    }

    /// Per-page channel table, in page order. The §Incremental scheduler
    /// uses the prefix covering a workload's `kv_len` both as a memo key
    /// (two steps with equal tables place identical traffic) and to build
    /// the per-entry channel mask for the disjointness gate.
    pub fn channels(&self) -> &[u32] {
        &self.channels
    }

    /// Drop every allocated page *and* the table's backing allocation,
    /// keeping the page size. [`PageMap::reset`] keeps capacity for the
    /// preemption → rebuild cycle; `release` is for requests that are done
    /// for good — at million-request scale the retired states would
    /// otherwise pin O(total requests × pages) of dead table memory.
    pub fn release(&mut self) {
        self.channels = Vec::new();
    }

    /// Drop every allocated page, keeping the page size. This is the
    /// preemption/eviction primitive: a preempted request's KV pages are
    /// returned to the pool and its cache must be rebuilt by *real*
    /// re-prefill traffic (the router re-emits chunked prefill), so the
    /// cost of eviction is paid in simulated cycles, not waved away.
    pub fn reset(&mut self) {
        self.channels.clear();
    }

    /// Channel holding page `page`. Panics if the page was never
    /// allocated — builders must size the map before emission.
    pub fn channel_of_page(&self, page: u64) -> u32 {
        self.channels[page as usize]
    }

    /// Channel holding the page that contains token `tok`.
    pub fn channel_of_token(&self, tok: u64) -> u32 {
        self.channels[(tok / self.page_tokens) as usize]
    }

    /// Split the token range `[tok0, tok0 + ntok)` into `(channel, bytes)`
    /// transfer segments at page granularity, merging adjacent pages that
    /// landed on the same channel (contiguous same-channel tokens are one
    /// DMA). `bytes_per_token` carries the K+V payload per position.
    pub fn segments(&self, tok0: u64, ntok: u64, bytes_per_token: u64, out: &mut Vec<(u32, u64)>) {
        out.clear();
        let end = tok0 + ntok;
        let mut t = tok0;
        while t < end {
            let page = t / self.page_tokens;
            let page_end = ((page + 1) * self.page_tokens).min(end);
            let chan = self.channels[page as usize];
            let bytes = (page_end - t) * bytes_per_token;
            match out.last_mut() {
                Some(last) if last.0 == chan => last.1 += bytes,
                _ => out.push((chan, bytes)),
            }
            t = page_end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_monotonically_and_in_order() {
        let mut pm = PageMap::new(16);
        let mut asked = Vec::new();
        pm.grow_to(40, |p| {
            asked.push(p);
            p as u32
        });
        assert_eq!(asked, vec![0, 1, 2]);
        assert_eq!(pm.num_pages(), 3);
        assert_eq!(pm.tokens_capacity(), 48);
        // Growing to a smaller/equal size allocates nothing new.
        pm.grow_to(48, |_| panic!("no new pages expected"));
        pm.grow_to(49, |p| p as u32);
        assert_eq!(pm.num_pages(), 4);
        assert_eq!(pm.channel_of_token(47), 2);
        assert_eq!(pm.channel_of_page(3), 3);
    }

    #[test]
    fn segments_split_and_merge_by_channel() {
        let mut pm = PageMap::new(8);
        // Channels per page: 0 0 1 2 2 — adjacent same-channel pages merge.
        let chans = [0u32, 0, 1, 2, 2];
        pm.grow_to(40, |p| chans[p as usize]);
        let mut out = Vec::new();
        pm.segments(0, 40, 4, &mut out);
        assert_eq!(out, vec![(0, 64), (1, 32), (2, 64)]);
        // A sub-range honoring partial first/last pages: [6, 18) spans the
        // merged channel-0 run and two tokens of the channel-1 page.
        pm.segments(6, 12, 4, &mut out);
        assert_eq!(out, vec![(0, 40), (1, 8)]);
        // A range within one page.
        pm.segments(17, 3, 4, &mut out);
        assert_eq!(out, vec![(1, 12)]);
        // Byte conservation: segments always sum to ntok · bytes_per_token.
        for (t0, n) in [(0u64, 40u64), (3, 21), (8, 8), (39, 1)] {
            pm.segments(t0, n, 4, &mut out);
            let total: u64 = out.iter().map(|&(_, b)| b).sum();
            assert_eq!(total, n * 4, "range ({t0}, {n})");
        }
    }

    #[test]
    fn empty_range_yields_no_segments() {
        let mut pm = PageMap::new(8);
        pm.grow_to(8, |_| 0);
        let mut out = vec![(9u32, 9u64)];
        pm.segments(3, 0, 4, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "page size")]
    fn zero_page_size_rejected() {
        let _ = PageMap::new(0);
    }

    #[test]
    fn channels_exposes_the_table_and_release_frees_it() {
        let mut pm = PageMap::new(16);
        pm.grow_to(160, |p| p as u32);
        let want: Vec<u32> = (0..10).collect();
        assert_eq!(pm.channels(), want.as_slice());
        pm.release();
        assert_eq!(pm.num_pages(), 0);
        assert!(pm.channels().is_empty());
        assert_eq!(pm.page_tokens(), 16);
        // A released map still grows correctly from page 0.
        pm.grow_to(20, |p| (p + 3) as u32);
        assert_eq!(pm.channel_of_page(0), 3);
    }

    #[test]
    fn reset_drops_pages_but_keeps_page_size() {
        let mut pm = PageMap::new(16);
        pm.grow_to(40, |p| p as u32);
        assert_eq!(pm.num_pages(), 3);
        pm.reset();
        assert_eq!(pm.num_pages(), 0);
        assert_eq!(pm.tokens_capacity(), 0);
        assert_eq!(pm.page_tokens(), 16);
        // Regrowth re-asks the allocator from page 0 — a rebuilt cache may
        // land on entirely different channels.
        pm.grow_to(20, |p| (p + 7) as u32);
        assert_eq!(pm.channel_of_page(0), 7);
    }
}
