//! HBM channel placement and address→channel mapping.
//!
//! Per the paper's Fig. 1 floorplan, HBM stacks sit at the die boundary:
//! `channels_west` memory controllers along the west edge and
//! `channels_south` along the south edge (Table I: 16 × 2). Each west
//! channel serves a contiguous band of mesh rows and each south channel a
//! band of columns, so row-streamed tensors (Q, O) naturally load through
//! the west edge and column-streamed tensors (K, V) through the south edge
//! — this is what makes FlatAttention's edge-loading scheme contention
//! free when slices are distributed over a group.
//!
//! Serving extension: [`paged::PageMap`] generalizes the static mappings
//! to page-granular KV-cache placement — each request's cache pages land
//! on whatever channel the scheduler's placement policy chose, so paged
//! fragmentation becomes real channel contention in the simulator.

pub mod map;
pub mod paged;

pub use map::{ChannelRef, Edge, HbmMap};
pub use paged::PageMap;
