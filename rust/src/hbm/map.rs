//! Tile↔HBM-channel mapping.

use crate::arch::ArchConfig;
use crate::noc::Topology;

/// Which die edge a channel is attached to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Edge {
    /// West edge (x = 0).
    West,
    /// South edge (y = y_dim - 1).
    South,
}

/// A resolved channel reference: global channel index (west channels first,
/// then south) plus the XY hop distance from the requesting tile to the
/// channel's edge attachment point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelRef {
    /// Global channel index (west channels first, then south).
    pub index: usize,
    /// XY hop distance from the requesting tile.
    pub hops: u64,
}

/// Static channel map derived from an [`ArchConfig`].
#[derive(Debug, Clone)]
pub struct HbmMap {
    topo: Topology,
    channels_west: usize,
    channels_south: usize,
}

impl HbmMap {
    /// Build the channel map.
    ///
    /// Panics with a diagnosable message when the architecture has no HBM
    /// channels on either edge: `row_channel` and `col_channel` fall back
    /// to each other when their own edge is empty, so a both-edges-empty
    /// config would otherwise recurse until the stack overflows.
    pub fn new(arch: &ArchConfig) -> Self {
        assert!(
            arch.hbm.total_channels() > 0,
            "ArchConfig '{}' has zero HBM channels on both edges; at least one west or south \
             channel is required (see ArchConfig::validate)",
            arch.name
        );
        Self {
            topo: Topology::new(arch.mesh_x, arch.mesh_y),
            channels_west: arch.hbm.channels_west,
            channels_south: arch.hbm.channels_south,
        }
    }

    /// West + south channel count.
    pub fn total_channels(&self) -> usize {
        self.channels_west + self.channels_south
    }

    /// Channel serving row-streamed (Q/O) traffic for the tile at `(x, y)`.
    /// Rows are divided into `channels_west` contiguous bands.
    ///
    /// Falls back to a south channel when the west edge has none.
    pub fn row_channel(&self, x: usize, y: usize) -> ChannelRef {
        if self.channels_west == 0 {
            return self.col_channel(x, y);
        }
        let index = y * self.channels_west / self.topo.y_dim;
        ChannelRef {
            index,
            hops: self.topo.hops_to_west_edge(x, y),
        }
    }

    /// Channel serving column-streamed (K/V) traffic for the tile at
    /// `(x, y)`. Columns are divided into `channels_south` bands.
    pub fn col_channel(&self, x: usize, y: usize) -> ChannelRef {
        if self.channels_south == 0 {
            return self.row_channel(x, y);
        }
        let index = self.channels_west + x * self.channels_south / self.topo.x_dim;
        ChannelRef {
            index,
            hops: self.topo.hops_to_south_edge(x, y),
        }
    }

    /// XY hop count from the tile at `(x, y)` to an *arbitrary* channel's
    /// edge attachment point (west channels first, then south) — the
    /// page-granular generalization of the fixed row/column mappings
    /// above, used when a paged KV cache places a transfer on whatever
    /// channel its page table dictates.
    pub fn channel_hops(&self, x: usize, y: usize, chan: usize) -> u64 {
        debug_assert!(chan < self.total_channels());
        if chan < self.channels_west {
            // West edge: travel to x = 0 plus the row offset to the
            // channel's band.
            let row = chan * self.topo.y_dim / self.channels_west.max(1);
            (x + row.abs_diff(y)) as u64
        } else {
            let c = chan - self.channels_west;
            let col = c * self.topo.x_dim / self.channels_south.max(1);
            (col.abs_diff(x) + (self.topo.y_dim - 1 - y)) as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn table1_row_bands() {
        let m = HbmMap::new(&presets::table1());
        // 32 rows over 16 west channels: 2 rows per channel.
        assert_eq!(m.row_channel(0, 0).index, 0);
        assert_eq!(m.row_channel(0, 1).index, 0);
        assert_eq!(m.row_channel(0, 2).index, 1);
        assert_eq!(m.row_channel(0, 31).index, 15);
    }

    #[test]
    fn table1_col_bands_offset() {
        let m = HbmMap::new(&presets::table1());
        assert_eq!(m.col_channel(0, 0).index, 16);
        assert_eq!(m.col_channel(31, 0).index, 31);
    }

    #[test]
    fn hops_match_edge_distance() {
        let m = HbmMap::new(&presets::table1());
        assert_eq!(m.row_channel(5, 0).hops, 5);
        assert_eq!(m.col_channel(0, 31).hops, 0);
        assert_eq!(m.col_channel(0, 0).hops, 31);
    }

    #[test]
    fn balanced_coverage() {
        // Every channel serves the same number of rows/columns on Table I.
        let arch = presets::table1();
        let m = HbmMap::new(&arch);
        let mut counts = vec![0usize; m.total_channels()];
        for y in 0..arch.mesh_y {
            counts[m.row_channel(0, y).index] += 1;
        }
        for x in 0..arch.mesh_x {
            counts[m.col_channel(x, 0).index] += 1;
        }
        assert!(counts.iter().all(|&c| c == 2), "{counts:?}");
    }

    #[test]
    #[should_panic(expected = "zero HBM channels")]
    fn zero_channels_on_both_edges_is_rejected() {
        // Regression: `row_channel` ⇄ `col_channel` used to recurse to a
        // stack overflow on this config; now construction fails loudly.
        let mut arch = presets::table2(8);
        arch.hbm.channels_west = 0;
        arch.hbm.channels_south = 0;
        let _ = HbmMap::new(&arch);
    }

    #[test]
    fn single_edge_fallbacks_terminate() {
        // One empty edge is a valid degenerate config: the empty edge's
        // lookup falls back to the populated one exactly once.
        let mut south_only = presets::table2(8);
        south_only.hbm.channels_west = 0;
        let m = HbmMap::new(&south_only);
        assert_eq!(m.row_channel(3, 3).index, m.col_channel(3, 3).index);

        let mut west_only = presets::table2(8);
        west_only.hbm.channels_south = 0;
        let m2 = HbmMap::new(&west_only);
        assert_eq!(m2.col_channel(5, 2).index, m2.row_channel(5, 2).index);
        assert!(m2.col_channel(5, 2).index < m2.total_channels());
    }

    #[test]
    fn channel_hops_consistent_with_edge_mappings() {
        let arch = presets::table1();
        let m = HbmMap::new(&arch);
        // A tile's own row/column channel sits at its edge-aligned
        // attachment: channel_hops agrees with the fixed mappings on
        // band-start rows/columns (the generic lookup measures to the
        // band's attachment point; Table I bands are 2 wide).
        for (x, y) in [(0usize, 0usize), (6, 12), (30, 30), (16, 2)] {
            let row = m.row_channel(x, y);
            assert_eq!(m.channel_hops(x, y, row.index), row.hops, "row ({x},{y})");
            let col = m.col_channel(x, y);
            assert_eq!(m.channel_hops(x, y, col.index), col.hops, "col ({x},{y})");
        }
        // A distant channel costs the extra band distance.
        assert_eq!(m.channel_hops(0, 0, 15), 30); // west chan 15 serves rows 30-31
        assert_eq!(m.channel_hops(0, 31, 16), 0); // south chan 16 at column 0
    }

    #[test]
    fn fewer_channels_than_rows() {
        let arch = presets::with_hbm_channels(presets::table2(32), 4);
        let m = HbmMap::new(&arch);
        // 32 rows over 4 channels: 8 rows per channel.
        assert_eq!(m.row_channel(0, 7).index, 0);
        assert_eq!(m.row_channel(0, 8).index, 1);
        assert_eq!(m.row_channel(0, 31).index, 3);
    }
}
