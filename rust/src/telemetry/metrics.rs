//! Deterministic, memory-bounded metrics registry.
//!
//! Everything here is a pure function of the virtual-clock event stream the
//! scheduler feeds in: counters and gauges are `u64`, histograms use fixed
//! log2 buckets, and timeseries use windowed aggregation whose re-bucketing
//! rule commutes with attribution (see [`WindowSeries`]). No wall-clock is
//! ever read, so two runs that produce the same serving schedule — e.g. the
//! same workload at different `--threads`, or full-rebuild vs incremental vs
//! memoized composition — export byte-identical snapshots.
//!
//! The one deliberate exception is the `engine_` name prefix: counters under
//! it describe *how the simulator computed* the run (composer patch/memo hit
//! rates), which is mode-dependent by design. `to_prometheus(false)` /
//! `to_json(false)` exclude them; the determinism wall compares those
//! deterministic snapshots, while the full export (`include_engine = true`)
//! is what the CLI and benches read.

use crate::sim::Cycle;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Name prefix for mode-dependent simulator-internals metrics, excluded from
/// the deterministic snapshot.
pub const ENGINE_PREFIX: &str = "engine_";

/// Hard cap on windows per series; on overflow the window length doubles and
/// adjacent windows merge, keeping memory O(1) for arbitrarily long runs.
pub const MAX_WINDOWS: usize = 256;

/// Default window length in cycles for per-run timeseries.
pub const DEFAULT_WINDOW_CYCLES: Cycle = 4096;

/// Number of log2 histogram buckets (bucket `i` holds values with bit-length
/// `i`, i.e. `v in [2^(i-1), 2^i)`; bucket 0 holds exactly 0).
pub const HIST_BUCKETS: usize = 65;

/// Fixed-bucket log2 histogram of `u64` samples.
///
/// Bucket index is the sample's bit length, so recording is branch-free and
/// the footprint is a constant 65 counters regardless of sample count.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hist {
    counts: [u64; HIST_BUCKETS],
    sum: u128,
    n: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { counts: [0; HIST_BUCKETS], sum: 0, n: 0 }
    }
}

impl Hist {
    fn bucket(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Record one sample.
    pub fn observe(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.sum += v as u128;
        self.n += 1;
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of all samples.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Inclusive upper bound of bucket `i` (`2^i - 1`); the last bucket is
    /// unbounded and rendered as `+Inf`.
    fn upper(i: usize) -> u64 {
        if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Deterministic upper bound (exclusive of empty tail) on the sample
    /// distribution: the smallest bucket bound at or below which a fraction
    /// `q` (in per-mille to stay integral) of samples fall.
    pub fn quantile_upper(&self, per_mille: u64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let target = (self.n * per_mille).div_ceil(1000);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::upper(i);
            }
        }
        u64::MAX
    }

    fn to_json(&self) -> Json {
        let hi = self.counts.iter().rposition(|&c| c != 0).map(|i| i + 1).unwrap_or(0);
        let buckets: Vec<Json> = self.counts[..hi]
            .iter()
            .enumerate()
            .map(|(i, &c)| Json::Arr(vec![Json::num(Self::upper(i) as f64), Json::num(c as f64)]))
            .collect();
        Json::obj([
            ("count", Json::num(self.n as f64)),
            ("sum", Json::num(self.sum as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Windowed timeseries over virtual time with a hard window-count cap.
///
/// Each `add(at, amount)` attributes the whole amount to the window that
/// contains `at`. When an index would exceed [`MAX_WINDOWS`], the window
/// length doubles and adjacent windows merge pairwise. Because windows are
/// aligned at cycle 0 and only ever double, `floor(at / w)` after a doubling
/// equals `floor(floor(at / w_old) / 2)` — attribution commutes with
/// re-bucketing, so the final series is a function of the event stream alone,
/// independent of when (or whether) doublings happened mid-run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WindowSeries {
    window: Cycle,
    vals: Vec<u64>,
}

impl WindowSeries {
    /// An empty series with the given window width (cycles; min 1).
    pub fn new(window: Cycle) -> Self {
        WindowSeries { window: window.max(1), vals: Vec::new() }
    }

    /// Accumulate `amount` into the window containing cycle `at`.
    pub fn add(&mut self, at: Cycle, amount: u64) {
        let mut idx = (at / self.window) as usize;
        while idx >= MAX_WINDOWS {
            self.rebucket();
            idx = (at / self.window) as usize;
        }
        if self.vals.len() <= idx {
            self.vals.resize(idx + 1, 0);
        }
        self.vals[idx] += amount;
    }

    fn rebucket(&mut self) {
        self.window = self.window.saturating_mul(2);
        let half = self.vals.len().div_ceil(2);
        for i in 0..half {
            let a = self.vals[2 * i];
            let b = self.vals.get(2 * i + 1).copied().unwrap_or(0);
            self.vals[i] = a + b;
        }
        self.vals.truncate(half);
    }

    /// Current window width in cycles (doubles as the series compacts).
    pub fn window(&self) -> Cycle {
        self.window
    }

    /// Per-window accumulated values.
    pub fn values(&self) -> &[u64] {
        &self.vals
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("window_cycles", Json::num(self.window as f64)),
            ("values", Json::Arr(self.vals.iter().map(|&v| Json::num(v as f64)).collect())),
        ])
    }
}

/// A set of parallel windowed lanes (one per HBM channel / per slot), all
/// sharing the same window length because every step feeds every lane.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LaneSet {
    totals: Vec<u64>,
    windows: Vec<WindowSeries>,
}

impl LaneSet {
    /// Grow to at least `lanes` lanes.
    pub fn ensure(&mut self, lanes: usize) {
        while self.totals.len() < lanes {
            self.totals.push(0);
            self.windows.push(WindowSeries::new(DEFAULT_WINDOW_CYCLES));
        }
    }

    /// Add one step's per-lane amounts, attributed at virtual time `at`.
    /// Zero amounts are added too so every lane keeps the same window shape.
    pub fn add(&mut self, at: Cycle, amounts: &[u64]) {
        self.ensure(amounts.len());
        for (lane, &v) in amounts.iter().enumerate() {
            self.totals[lane] += v;
            self.windows[lane].add(at, v);
        }
    }

    /// Per-lane running totals.
    pub fn totals(&self) -> &[u64] {
        &self.totals
    }

    /// Per-lane windowed series.
    pub fn windows(&self) -> &[WindowSeries] {
        &self.windows
    }

    fn footprint(&self) -> usize {
        self.totals.len() + self.windows.iter().map(|w| w.vals.len()).sum::<usize>()
    }

    fn to_json(&self) -> Json {
        let window = self.windows.first().map(|w| w.window).unwrap_or(DEFAULT_WINDOW_CYCLES);
        Json::obj([
            ("totals", Json::Arr(self.totals.iter().map(|&v| Json::num(v as f64)).collect())),
            ("window_cycles", Json::num(window as f64)),
            (
                "windows",
                Json::Arr(
                    self.windows
                        .iter()
                        .map(|w| {
                            Json::Arr(w.vals.iter().map(|&v| Json::num(v as f64)).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The run-wide registry. Names are `&'static str` so recording never
/// allocates; iteration order (BTreeMap) is stable, so text exports are
/// deterministic.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, u64>,
    hists: BTreeMap<&'static str, Hist>,
    series: BTreeMap<&'static str, WindowSeries>,
    /// Per-HBM-channel busy cycles (scheduled occupancy demand).
    pub hbm_chan_busy: LaneSet,
    /// Per-slot NoC-collective busy cycles (SumReduce/MaxReduce/Multicast).
    pub noc_slot_busy: LaneSet,
    /// Per-transformer-layer batch entries (lane = layer index): how many
    /// step entries ran each layer, over virtual time. Empty unless the
    /// run serves full layers (`SchedulerConfig::ffn_mult >= 1`).
    pub layer_entries: LaneSet,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `v` to a counter.
    pub fn inc(&mut self, name: &'static str, v: u64) {
        *self.counters.entry(name).or_insert(0) += v;
    }

    /// Overwrite a counter.
    pub fn set_counter(&mut self, name: &'static str, v: u64) {
        self.counters.insert(name, v);
    }

    /// Counter value (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Overwrite a gauge.
    pub fn gauge_set(&mut self, name: &'static str, v: u64) {
        self.gauges.insert(name, v);
    }

    /// Raise a gauge to at least `v`.
    pub fn gauge_max(&mut self, name: &'static str, v: u64) {
        let g = self.gauges.entry(name).or_insert(0);
        *g = (*g).max(v);
    }

    /// Gauge value (0 when never touched).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    /// Record a sample into a named histogram.
    pub fn observe(&mut self, name: &'static str, v: u64) {
        self.hists.entry(name).or_default().observe(v);
    }

    /// Named histogram, if any sample was recorded.
    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    /// Accumulate into a named windowed series.
    pub fn series_add(&mut self, name: &'static str, at: Cycle, amount: u64) {
        self.series
            .entry(name)
            .or_insert_with(|| WindowSeries::new(DEFAULT_WINDOW_CYCLES))
            .add(at, amount);
    }

    /// Named windowed series, if ever written.
    pub fn series(&self, name: &str) -> Option<&WindowSeries> {
        self.series.get(name)
    }

    /// Approximate element count of everything stored — the memory-bound
    /// test asserts this is O(windows + buckets), never O(requests).
    pub fn footprint(&self) -> usize {
        self.counters.len()
            + self.gauges.len()
            + self.hists.len() * HIST_BUCKETS
            + self.series.values().map(|s| s.vals.len()).sum::<usize>()
            + self.hbm_chan_busy.footprint()
            + self.noc_slot_busy.footprint()
            + self.layer_entries.footprint()
    }

    fn keep(name: &str, include_engine: bool) -> bool {
        include_engine || !name.starts_with(ENGINE_PREFIX)
    }

    /// Prometheus-style text snapshot. Integer-formatted throughout, so the
    /// deterministic subset (`include_engine = false`) is byte-comparable.
    pub fn to_prometheus(&self, include_engine: bool) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            if Self::keep(name, include_engine) {
                let _ = writeln!(out, "# TYPE flatattn_{name} counter");
                let _ = writeln!(out, "flatattn_{name} {v}");
            }
        }
        for (name, v) in &self.gauges {
            if Self::keep(name, include_engine) {
                let _ = writeln!(out, "# TYPE flatattn_{name} gauge");
                let _ = writeln!(out, "flatattn_{name} {v}");
            }
        }
        for (name, h) in &self.hists {
            if !Self::keep(name, include_engine) {
                continue;
            }
            let _ = writeln!(out, "# TYPE flatattn_{name} histogram");
            let mut cum = 0u64;
            let hi = h.counts.iter().rposition(|&c| c != 0).map(|i| i + 1).unwrap_or(0);
            for (i, &c) in h.counts[..hi].iter().enumerate() {
                cum += c;
                let _ = writeln!(out, "flatattn_{name}_bucket{{le=\"{}\"}} {cum}", Hist::upper(i));
            }
            let _ = writeln!(out, "flatattn_{name}_bucket{{le=\"+Inf\"}} {}", h.n);
            let _ = writeln!(out, "flatattn_{name}_sum {}", h.sum);
            let _ = writeln!(out, "flatattn_{name}_count {}", h.n);
        }
        for (lane, &v) in self.hbm_chan_busy.totals().iter().enumerate() {
            let _ = writeln!(out, "flatattn_hbm_channel_busy_cycles{{channel=\"{lane}\"}} {v}");
        }
        for (lane, &v) in self.noc_slot_busy.totals().iter().enumerate() {
            let _ = writeln!(out, "flatattn_noc_slot_busy_cycles{{slot=\"{lane}\"}} {v}");
        }
        for (lane, &v) in self.layer_entries.totals().iter().enumerate() {
            let _ = writeln!(out, "flatattn_layer_entries{{layer=\"{lane}\"}} {v}");
        }
        out
    }

    /// JSON snapshot mirroring the Prometheus export plus the windowed
    /// series (which have no Prometheus text form).
    pub fn to_json(&self, include_engine: bool) -> Json {
        let pick = |m: &BTreeMap<&'static str, u64>| {
            Json::Obj(
                m.iter()
                    .filter(|(k, _)| Self::keep(k, include_engine))
                    .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                    .collect(),
            )
        };
        Json::obj([
            ("counters", pick(&self.counters)),
            ("gauges", pick(&self.gauges)),
            (
                "histograms",
                Json::Obj(
                    self.hists
                        .iter()
                        .filter(|(k, _)| Self::keep(k, include_engine))
                        .map(|(k, h)| (k.to_string(), h.to_json()))
                        .collect(),
                ),
            ),
            (
                "series",
                Json::Obj(
                    self.series
                        .iter()
                        .filter(|(k, _)| Self::keep(k, include_engine))
                        .map(|(k, s)| (k.to_string(), s.to_json()))
                        .collect(),
                ),
            ),
            ("hbm_channel_busy", self.hbm_chan_busy.to_json()),
            ("noc_slot_busy", self.noc_slot_busy.to_json()),
            ("layer_entries", self.layer_entries.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_by_bit_length() {
        let mut h = Hist::default();
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1 << 40] {
            h.observe(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.sum(), 25 + (1u128 << 40));
        assert_eq!(h.counts[0], 1); // 0
        assert_eq!(h.counts[1], 1); // 1
        assert_eq!(h.counts[2], 2); // 2, 3
        assert_eq!(h.counts[3], 2); // 4, 7
        assert_eq!(h.counts[4], 1); // 8
        assert_eq!(h.counts[41], 1); // 2^40
    }

    #[test]
    fn hist_quantiles_are_bucket_bounds() {
        let mut h = Hist::default();
        for v in 1..=100u64 {
            h.observe(v);
        }
        // p50 of 1..=100 lands in the bucket holding 32..63 (cum 63 ≥ 50);
        // p100 in the bucket holding 64..127.
        assert_eq!(h.quantile_upper(500), 63);
        assert_eq!(h.quantile_upper(1000), 127);
        assert_eq!(Hist::default().quantile_upper(500), 0);
    }

    #[test]
    fn window_series_rebucket_commutes() {
        // Feed the same stream into a series with a tiny window (forcing
        // many doublings) and one pre-sized so no doubling happens; final
        // shapes must agree after aligning window lengths.
        let mut a = WindowSeries::new(1);
        let mut b = WindowSeries::new(1 << 10);
        for t in (0..100_000u64).step_by(97) {
            a.add(t, t % 13);
            b.add(t, t % 13);
        }
        while a.window() < b.window() {
            a.rebucket();
        }
        while b.window() < a.window() {
            b.rebucket();
        }
        assert_eq!(a.window(), b.window());
        // Trailing zeros may differ (resize happens lazily); compare sums.
        let pad = |v: &[u64], n: usize| {
            let mut v = v.to_vec();
            v.resize(n, 0);
            v
        };
        let n = a.values().len().max(b.values().len());
        assert_eq!(pad(a.values(), n), pad(b.values(), n));
        assert!(a.values().len() <= MAX_WINDOWS);
    }

    #[test]
    fn window_series_is_bounded() {
        let mut s = WindowSeries::new(DEFAULT_WINDOW_CYCLES);
        for t in (0..1u64 << 42).step_by(1 << 30) {
            s.add(t, 1);
        }
        assert!(s.values().len() <= MAX_WINDOWS);
    }

    #[test]
    fn registry_snapshot_is_deterministic_and_filters_engine() {
        let mk = || {
            let mut r = MetricsRegistry::new();
            r.inc("steps_total", 3);
            r.inc("engine_steps_patched", 2);
            r.gauge_max("peak_queue_depth", 5);
            r.observe("step_makespan_cycles", 1000);
            r.series_add("hbm_bytes", 0, 64);
            r.hbm_chan_busy.add(0, &[10, 0, 3]);
            r
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.to_prometheus(false), b.to_prometheus(false));
        assert!(!a.to_prometheus(false).contains("engine_"));
        assert!(a.to_prometheus(true).contains("engine_steps_patched"));
        assert!(a.to_json(false).to_string() == b.to_json(false).to_string());
        assert_eq!(a.counter("engine_steps_patched"), 2);
        assert_eq!(a.hbm_chan_busy.totals(), &[10, 0, 3]);
    }
}
