//! Request-lifecycle event stream and its chrome-trace export.
//!
//! The scheduler/router push [`LifeEvent`]s anchored on the virtual clock;
//! [`TraceCollector`] buffers them and renders one Perfetto/chrome-trace
//! JSON for the whole serving run: the machine is pid 0 (step slices and
//! fault/band-death instants), each request is its own pid (`request + 1`)
//! carrying queued spans, per-step prefill/decode slices, and
//! first-token/completed/requeue instants with cause labels.
//!
//! §Time units — the one convention shared with `sim::trace`: chrome-trace
//! `ts`/`dur` fields are microseconds by definition, and we write **one
//! simulated cycle per microsecond**. With [`CHROME_DISPLAY_UNIT`] `"ms"`
//! the viewer's readout of "1 ms" therefore means 1000 cycles (1 µs of real
//! time at the 1 GHz reference clock). Build the top-level document through
//! [`chrome_trace_doc`] so every exporter stays on this convention.

use crate::sim::Cycle;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// `displayTimeUnit` for every chrome-trace export in this crate. See the
/// module doc: 1 cycle = 1 µs in `ts`/`dur`, so "1 ms" on screen = 1000
/// cycles.
pub const CHROME_DISPLAY_UNIT: &str = "ms";

/// Wrap a `traceEvents` array in the crate-wide chrome-trace envelope.
pub fn chrome_trace_doc(events: Vec<Json>) -> Json {
    Json::obj([
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str(CHROME_DISPLAY_UNIT)),
    ])
}

/// Why a request went back to the waiting queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequeueCause {
    /// The tile-row band hosting the request died mid-run.
    BandDeath,
    /// Deadline overrun with retries remaining; restarted from scratch.
    DeadlineRetry,
    /// Preempted to relieve KV page pressure.
    Preemption,
}

impl RequeueCause {
    /// Stable lowercase name for counters and trace JSON.
    pub fn label(self) -> &'static str {
        match self {
            RequeueCause::BandDeath => "band-death",
            RequeueCause::DeadlineRetry => "deadline-retry",
            RequeueCause::Preemption => "preemption",
        }
    }
}

/// Why a request was dropped from the run entirely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropCause {
    /// Deadline overrun with no retries left.
    RetriesExhausted,
    /// Every band was dead; nothing could ever run it.
    NoLiveBand,
    /// Its KV footprint alone exceeds the page pool.
    PoolTooSmall,
}

impl DropCause {
    /// Stable lowercase name for counters and trace JSON.
    pub fn label(self) -> &'static str {
        match self {
            DropCause::RetriesExhausted => "retries-exhausted",
            DropCause::NoLiveBand => "no-live-band",
            DropCause::PoolTooSmall => "pool-too-small",
        }
    }
}

/// One virtual-clock-stamped lifecycle event. The stream is generated in
/// scheduling order, which is deterministic across thread counts and
/// composer modes, so the exported trace is too.
#[derive(Clone, Debug, PartialEq)]
pub enum LifeEvent {
    /// Request entered the waiting queue (at its arrival, or on requeue).
    Queued { req: u32, t: Cycle },
    /// Request admitted into a batch slot.
    Admitted { req: u32, slot: u32, t: Cycle },
    /// One step's worth of work for one request (a prefill chunk or a
    /// decode step), spanning the composed step's interval.
    Slice { req: u32, prefill: bool, tokens: u64, start: Cycle, end: Cycle },
    /// First output token produced (TTFT anchor; re-armed after requeues).
    FirstToken { req: u32, t: Cycle },
    /// Request finished its full output.
    Completed { req: u32, t: Cycle },
    /// Request pushed back to the queue with a cause.
    Requeued { req: u32, t: Cycle, cause: RequeueCause },
    /// Request dropped from the run with a cause.
    Dropped { req: u32, t: Cycle, cause: DropCause },
    /// A tile-row band was first observed dead.
    BandDead { slot: u32, t: Cycle },
    /// One composed step on the machine lane.
    Step { index: u64, start: Cycle, end: Cycle, entries: u32, hbm_bytes: u64 },
    /// A fault-plan window hit this step; `detail` carries the DES stall
    /// diagnostics that previously went only to stderr.
    Fault { t: Cycle, killed: u32, stalled: u32, detail: String },
}

/// Buffers the run's event stream. Memory is O(steps + lifecycle events) —
/// proportional to the trace being exported, never per token — and the
/// collector only exists when `--trace-out` asked for it.
#[derive(Clone, Debug, Default)]
pub struct TraceCollector {
    events: Vec<LifeEvent>,
}

/// Machine-lane tid for step slices.
const TID_STEPS: u32 = 0;
/// Machine-lane tid for fault / band-death instants.
const TID_EVENTS: u32 = 1;

impl TraceCollector {
    /// An empty collector.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a lifecycle event.
    pub fn push(&mut self, ev: LifeEvent) {
        self.events.push(ev);
    }

    /// Every recorded event, in arrival order.
    pub fn events(&self) -> &[LifeEvent] {
        &self.events
    }

    fn slice(name: &str, ts: Cycle, dur: Cycle, pid: u32, tid: u32, args: Json) -> Json {
        Json::obj([
            ("name", Json::str(name.to_string())),
            ("ph", Json::str("X")),
            ("ts", Json::num(ts as f64)),
            ("dur", Json::num(dur as f64)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", args),
        ])
    }

    fn instant(name: &str, ts: Cycle, pid: u32, tid: u32, args: Json) -> Json {
        Json::obj([
            ("name", Json::str(name.to_string())),
            ("ph", Json::str("i")),
            ("s", Json::str("t")),
            ("ts", Json::num(ts as f64)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", args),
        ])
    }

    fn meta_process(pid: u32, name: &str) -> Json {
        Json::obj([
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj([("name", Json::str(name.to_string()))])),
        ])
    }

    fn pid_of(req: u32) -> u32 {
        req + 1
    }

    /// Render the buffered stream as one chrome-trace document.
    pub fn to_chrome_trace(&self) -> Json {
        let mut out: Vec<Json> = Vec::new();
        let mut pids: BTreeMap<u32, ()> = BTreeMap::new();
        let mut queued_since: BTreeMap<u32, Cycle> = BTreeMap::new();
        let mut saw_machine = false;

        for ev in &self.events {
            match *ev {
                LifeEvent::Queued { req, t } => {
                    pids.insert(Self::pid_of(req), ());
                    queued_since.insert(req, t);
                }
                LifeEvent::Admitted { req, slot, t } => {
                    let pid = Self::pid_of(req);
                    pids.insert(pid, ());
                    if let Some(q) = queued_since.remove(&req) {
                        out.push(Self::slice(
                            "queued",
                            q,
                            t.saturating_sub(q),
                            pid,
                            0,
                            Json::obj([("slot", Json::num(slot as f64))]),
                        ));
                    }
                }
                LifeEvent::Slice { req, prefill, tokens, start, end } => {
                    out.push(Self::slice(
                        if prefill { "prefill" } else { "decode" },
                        start,
                        end.saturating_sub(start),
                        Self::pid_of(req),
                        0,
                        Json::obj([("tokens", Json::num(tokens as f64))]),
                    ));
                }
                LifeEvent::FirstToken { req, t } => {
                    out.push(Self::instant(
                        "first-token",
                        t,
                        Self::pid_of(req),
                        0,
                        Json::obj([]),
                    ));
                }
                LifeEvent::Completed { req, t } => {
                    out.push(Self::instant("completed", t, Self::pid_of(req), 0, Json::obj([])));
                }
                LifeEvent::Requeued { req, t, cause } => {
                    out.push(Self::instant(
                        "requeue",
                        t,
                        Self::pid_of(req),
                        0,
                        Json::obj([("cause", Json::str(cause.label()))]),
                    ));
                    queued_since.insert(req, t);
                }
                LifeEvent::Dropped { req, t, cause } => {
                    let pid = Self::pid_of(req);
                    if let Some(q) = queued_since.remove(&req) {
                        out.push(Self::slice(
                            "queued",
                            q,
                            t.saturating_sub(q),
                            pid,
                            0,
                            Json::obj([]),
                        ));
                    }
                    out.push(Self::instant(
                        "expired",
                        t,
                        pid,
                        0,
                        Json::obj([("cause", Json::str(cause.label()))]),
                    ));
                }
                LifeEvent::BandDead { slot, t } => {
                    saw_machine = true;
                    out.push(Self::instant(
                        "band-dead",
                        t,
                        0,
                        TID_EVENTS,
                        Json::obj([("slot", Json::num(slot as f64))]),
                    ));
                }
                LifeEvent::Step { index, start, end, entries, hbm_bytes } => {
                    saw_machine = true;
                    out.push(Self::slice(
                        "step",
                        start,
                        end.saturating_sub(start),
                        0,
                        TID_STEPS,
                        Json::obj([
                            ("index", Json::num(index as f64)),
                            ("entries", Json::num(entries as f64)),
                            ("hbm_bytes", Json::num(hbm_bytes as f64)),
                        ]),
                    ));
                }
                LifeEvent::Fault { t, killed, stalled, ref detail } => {
                    saw_machine = true;
                    out.push(Self::instant(
                        "fault",
                        t,
                        0,
                        TID_EVENTS,
                        Json::obj([
                            ("killed", Json::num(killed as f64)),
                            ("stalled", Json::num(stalled as f64)),
                            ("detail", Json::str(detail.clone())),
                        ]),
                    ));
                }
            }
        }

        let mut events = Vec::with_capacity(out.len() + pids.len() + 1);
        if saw_machine {
            events.push(Self::meta_process(0, "machine"));
        }
        for &pid in pids.keys() {
            events.push(Self::meta_process(pid, &format!("request {}", pid - 1)));
        }
        events.extend(out);
        chrome_trace_doc(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queued_spans_pair_and_reopen() {
        let mut tc = TraceCollector::new();
        tc.push(LifeEvent::Queued { req: 3, t: 10 });
        tc.push(LifeEvent::Admitted { req: 3, slot: 1, t: 25 });
        tc.push(LifeEvent::Slice { req: 3, prefill: true, tokens: 96, start: 25, end: 40 });
        tc.push(LifeEvent::Requeued { req: 3, t: 40, cause: RequeueCause::BandDeath });
        tc.push(LifeEvent::Admitted { req: 3, slot: 2, t: 55 });
        tc.push(LifeEvent::FirstToken { req: 3, t: 70 });
        tc.push(LifeEvent::Completed { req: 3, t: 70 });
        let doc = tc.to_chrome_trace();
        assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some(CHROME_DISPLAY_UNIT));
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let queued: Vec<(f64, f64)> = evs
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("queued"))
            .map(|e| {
                (e.get("ts").unwrap().as_f64().unwrap(), e.get("dur").unwrap().as_f64().unwrap())
            })
            .collect();
        assert_eq!(queued, vec![(10.0, 15.0), (40.0, 15.0)]);
        // Everything lives on the request's pid (req + 1).
        for e in evs.iter().filter(|e| e.get("ph").and_then(Json::as_str) != Some("M")) {
            assert_eq!(e.get("pid").unwrap().as_f64(), Some(4.0));
        }
        assert!(Json::parse(&doc.to_string()).is_ok());
    }

    #[test]
    fn machine_lane_and_metadata() {
        let mut tc = TraceCollector::new();
        tc.push(LifeEvent::Step { index: 0, start: 0, end: 100, entries: 2, hbm_bytes: 4096 });
        tc.push(LifeEvent::Fault { t: 50, killed: 1, stalled: 2, detail: "x".into() });
        tc.push(LifeEvent::BandDead { slot: 3, t: 60 });
        let doc = tc.to_chrome_trace();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(
            evs[0].get("args").unwrap().get("name").unwrap().as_str(),
            Some("machine")
        );
        let step = evs.iter().find(|e| e.get("name").and_then(Json::as_str) == Some("step"));
        assert_eq!(step.unwrap().get("dur").unwrap().as_f64(), Some(100.0));
        let fault = evs.iter().find(|e| e.get("name").and_then(Json::as_str) == Some("fault"));
        assert_eq!(fault.unwrap().get("tid").unwrap().as_f64(), Some(1.0));
    }
}
