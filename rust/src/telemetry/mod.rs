//! Run-wide observability for serving runs: lifecycle tracing, streaming
//! metrics, and simulator self-profiling.
//!
//! # §Telemetry design
//!
//! The scheduler and router narrate a run as a stream of [`LifeEvent`]s
//! anchored on the virtual clock (request lifecycle transitions plus
//! machine-lane step slices and fault instants); the same stream drives
//! both the chrome-trace JSON written by `schedule --trace-out` (time-unit
//! convention in [`events`]) and the lifecycle counters/histograms in the
//! metrics registry. The deterministic snapshot is a pure function of the
//! serving schedule: busy fractions are occupancy sums (not achieved
//! service, hence thread-invariant), attribution uses stable identities
//! only (HBM channels by resource id, collective traffic per batch slot),
//! and mode-dependent composer counters live under the `engine_` prefix
//! and are excluded ([`metrics::ENGINE_PREFIX`]). Timeseries are bounded
//! by doubling windows ([`metrics::WindowSeries`]) so the registry
//! footprint is never O(requests). Telemetry is opt-in per run
//! (`Option<&mut RunTelemetry>`; `None` does no work and no allocation),
//! and wall-clock [`profile`] timers (`--profile`) are never part of
//! deterministic output. The full design essay — determinism argument,
//! window re-bucketing proof, cost model — lives in
//! `docs/ARCHITECTURE.md` §"Telemetry".

pub mod events;
pub mod metrics;
pub mod profile;

pub use events::{
    chrome_trace_doc, DropCause, LifeEvent, RequeueCause, TraceCollector, CHROME_DISPLAY_UNIT,
};
pub use metrics::{Hist, LaneSet, MetricsRegistry, WindowSeries, ENGINE_PREFIX, MAX_WINDOWS};
pub use profile::{ProfPhase, Profiler, ALL_PHASES};

use crate::sim::{Cycle, RunStats};
use crate::util::json::Json;

/// How the composer produced a step's stats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepMode {
    /// Composed from scratch and sealed.
    Rebuilt,
    /// Cached sealed program with costs patched in place.
    Patched,
    /// Merged from per-entry solo memo results; no batch program existed.
    Memoized,
}

/// Diagnostics captured on a faulted step (counts plus the DES stall
/// report that previously went only to stderr).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultNote {
    /// Ops killed outright (their tile died before issue).
    pub killed: u32,
    /// Ops stalled forever behind killed dependencies.
    pub stalled: u32,
    /// Human-readable stall report.
    pub detail: String,
}

/// Per-step resource attribution filled in by the `StepComposer` when a
/// telemetry sink is attached. Vectors are preallocated once and reused
/// every step; nothing here allocates on the hot path.
#[derive(Clone, Debug)]
pub struct StepProbe {
    /// Scheduled busy cycles per HBM channel (`ResourceId(c) == channel c`).
    pub chan_busy: Vec<u64>,
    /// Scheduled NoC-collective busy cycles per batch slot.
    pub noc_slot_busy: Vec<u64>,
    /// How the step program was obtained (rebuilt / memoized / patched).
    pub mode: StepMode,
    /// Fault diagnostics when the step ran degraded.
    pub fault: Option<FaultNote>,
}

impl StepProbe {
    /// A zeroed probe sized for `n_chan` channels and `slots` bands.
    pub fn new(n_chan: usize, slots: usize) -> Self {
        StepProbe {
            chan_busy: vec![0; n_chan],
            noc_slot_busy: vec![0; slots],
            mode: StepMode::Rebuilt,
            fault: None,
        }
    }

    /// Zero every per-step accumulator in place.
    pub fn reset(&mut self) {
        self.chan_busy.iter_mut().for_each(|v| *v = 0);
        self.noc_slot_busy.iter_mut().for_each(|v| *v = 0);
        self.mode = StepMode::Rebuilt;
        self.fault = None;
    }
}

/// Everything the scheduler observes about one composed step, handed to
/// [`RunTelemetry::record_step`].
pub struct StepObs<'a> {
    /// 0-based step number.
    pub index: u64,
    /// Virtual clock at step start.
    pub start: Cycle,
    /// Virtual clock at step end.
    pub end: Cycle,
    /// DES stats of the step's composed program.
    pub stats: &'a RunStats,
    /// Per-entry `(slot, request, is_prefill, tokens)` of the step batch.
    pub entries: &'a [(usize, usize, bool, u64)],
    /// Requests waiting for admission after this step.
    pub queue_depth: u64,
    /// KV pages allocated across live requests.
    pub pages_in_use: u64,
    /// Batch slots occupied this step.
    pub slots: u64,
    /// Optional per-channel / per-slot busy probe of this step.
    pub probe: Option<&'a StepProbe>,
    /// §Layer serving: per-transformer-layer entry counts of this step
    /// (`counts[l]` = entries that ran layer `l`), `None` for
    /// attention-only steps. Feeds the [`MetricsRegistry::layer_entries`]
    /// lanes and the pipelining counters.
    pub layer_counts: Option<&'a [u64]>,
}

/// The per-run telemetry sink threaded through `scheduler::simulate` /
/// `scheduler::route`. Metrics are always on once a sink exists; the trace
/// collector and profiler are further opt-ins.
#[derive(Debug, Default)]
pub struct RunTelemetry {
    /// Always-on counters / gauges / histograms / series.
    pub metrics: MetricsRegistry,
    /// Optional lifecycle trace collector.
    pub trace: Option<TraceCollector>,
    /// Optional self-profiler (wall-clock per scheduler phase).
    pub profile: Option<Profiler>,
}

impl RunTelemetry {
    /// A metrics-only sink (no trace, no profiler).
    pub fn new() -> Self {
        Self::default()
    }

    /// Also collect the lifecycle event stream for a chrome-trace export.
    pub fn with_trace(mut self) -> Self {
        self.trace = Some(TraceCollector::new());
        self
    }

    /// Also collect wall-clock phase timings (enables the global profiling
    /// gate so `Program::seal` reports verify time).
    pub fn with_profile(mut self) -> Self {
        profile::set_profiling(true);
        self.profile = Some(Profiler::new());
        self
    }

    fn event(&mut self, ev: LifeEvent) {
        if let Some(t) = self.trace.as_mut() {
            t.push(ev);
        }
    }

    /// A request entered the admission queue.
    pub fn on_queued(&mut self, req: usize, t: Cycle) {
        self.metrics.inc("requests_queued", 1);
        self.event(LifeEvent::Queued { req: req as u32, t });
    }

    /// A request was admitted into a batch slot.
    pub fn on_admitted(&mut self, req: usize, slot: usize, t: Cycle) {
        self.metrics.inc("requests_admitted", 1);
        self.event(LifeEvent::Admitted { req: req as u32, slot: slot as u32, t });
    }

    /// A request produced its first output token.
    pub fn on_first_token(&mut self, req: usize, t: Cycle) {
        self.event(LifeEvent::FirstToken { req: req as u32, t });
    }

    /// One output token produced (first or decode).
    pub fn on_token(&mut self) {
        self.metrics.inc("tokens_generated", 1);
    }

    /// Completion with final per-request metrics (matches `RequestMetrics`
    /// semantics: TTFT from arrival, TPOT over `output - 1` decode tokens).
    pub fn on_completed(
        &mut self,
        req: usize,
        t: Cycle,
        arrival: Cycle,
        first: Cycle,
        output: u64,
    ) {
        self.metrics.inc("requests_completed", 1);
        self.metrics.observe("ttft_cycles", first.saturating_sub(arrival));
        if output > 1 {
            self.metrics.observe("tpot_cycles", t.saturating_sub(first) / (output - 1));
        }
        self.event(LifeEvent::Completed { req: req as u32, t });
    }

    /// A request was bumped back to the queue.
    pub fn on_requeued(&mut self, req: usize, t: Cycle, cause: RequeueCause) {
        self.metrics.inc(
            match cause {
                RequeueCause::BandDeath => "requeue_band_death",
                RequeueCause::DeadlineRetry => "requeue_deadline_retry",
                RequeueCause::Preemption => "requeue_preemption",
            },
            1,
        );
        self.event(LifeEvent::Requeued { req: req as u32, t, cause });
    }

    /// A request was permanently dropped.
    pub fn on_dropped(&mut self, req: usize, t: Cycle, cause: DropCause) {
        self.metrics.inc("requests_expired", 1);
        self.event(LifeEvent::Dropped { req: req as u32, t, cause });
    }

    /// A slot's tile band was declared dead by the router.
    pub fn on_band_dead(&mut self, slot: usize, t: Cycle) {
        self.metrics.inc("bands_died", 1);
        self.event(LifeEvent::BandDead { slot: slot as u32, t });
    }

    /// Sample one composed step into the registry (and the trace, if on).
    pub fn record_step(&mut self, obs: &StepObs) {
        let t0 = self.profile.as_ref().map(|_| std::time::Instant::now());
        let mk = obs.end.saturating_sub(obs.start);
        let m = &mut self.metrics;
        m.inc("steps_total", 1);
        m.inc("hbm_bytes_total", obs.stats.hbm_bytes);
        m.inc("busy_slot_cycles", obs.entries.len() as u64 * mk);
        m.inc("slot_cycles", obs.slots * mk);
        m.observe("step_makespan_cycles", mk);
        m.observe("queue_depth", obs.queue_depth);
        m.observe("batch_entries", obs.entries.len() as u64);
        m.observe("pages_in_use", obs.pages_in_use);
        m.gauge_max("peak_queue_depth", obs.queue_depth);
        m.gauge_max("peak_pages_in_use", obs.pages_in_use);
        m.series_add("busy_slot_cycles", obs.start, obs.entries.len() as u64 * mk);
        m.series_add("slot_cycles", obs.start, obs.slots * mk);
        m.series_add("hbm_bytes", obs.start, obs.stats.hbm_bytes);
        let mut tokens = 0u64;
        for &(_, _, is_prefill, len) in obs.entries {
            if is_prefill {
                m.inc("prefill_entries", 1);
                m.inc("prefill_tokens", len);
            } else {
                m.inc("decode_entries", 1);
                tokens += 1;
            }
        }
        m.series_add("decode_tokens", obs.start, tokens);
        if let Some(counts) = obs.layer_counts {
            m.inc("layered_steps", 1);
            m.layer_entries.add(obs.start, counts);
            // A step whose entries sit at two or more distinct layer
            // indices is genuinely pipelining layers across tile bands.
            if counts.iter().filter(|&&c| c > 0).count() >= 2 {
                m.inc("pipelined_steps", 1);
            }
        }
        if let Some(p) = obs.probe {
            m.hbm_chan_busy.add(obs.start, &p.chan_busy);
            m.noc_slot_busy.add(obs.start, &p.noc_slot_busy);
            match p.mode {
                StepMode::Rebuilt => m.inc("engine_steps_rebuilt", 1),
                StepMode::Patched => m.inc("engine_steps_patched_live", 1),
                StepMode::Memoized => m.inc("engine_steps_memoized_live", 1),
            }
            if let Some(f) = &p.fault {
                m.inc("steps_faulted", 1);
                m.inc("ops_killed", f.killed as u64);
                m.inc("ops_stalled", f.stalled as u64);
                if self.trace.is_some() {
                    let ev = LifeEvent::Fault {
                        t: obs.start,
                        killed: f.killed,
                        stalled: f.stalled,
                        detail: f.detail.clone(),
                    };
                    self.event(ev);
                }
            }
        }
        if self.trace.is_some() {
            let step = LifeEvent::Step {
                index: obs.index,
                start: obs.start,
                end: obs.end,
                entries: obs.entries.len() as u32,
                hbm_bytes: obs.stats.hbm_bytes,
            };
            self.event(step);
            for &(_, req, is_prefill, len) in obs.entries {
                self.event(LifeEvent::Slice {
                    req: req as u32,
                    prefill: is_prefill,
                    tokens: len,
                    start: obs.start,
                    end: obs.end,
                });
            }
        }
        if let (Some(p), Some(t0)) = (self.profile.as_mut(), t0) {
            p.add_nanos(ProfPhase::Metrics, t0.elapsed().as_nanos() as u64);
        }
    }

    /// Final-clock bookkeeping once the run loop exits.
    pub fn finish_run(&mut self, clock: Cycle) {
        self.metrics.gauge_set("final_cycles", clock);
    }

    /// Fold another profiler's laps into this sink's profiler (if enabled).
    pub fn merge_profile(&mut self, other: &Profiler) {
        if let Some(p) = self.profile.as_mut() {
            p.merge(other);
        }
    }

    /// Deterministic JSON snapshot (the block embedded in `ServingReport`).
    pub fn snapshot_json(&self) -> Json {
        self.metrics.to_json(false)
    }

    /// Chrome-trace document of the collected lifecycle stream, if tracing.
    pub fn trace_json(&self) -> Option<Json> {
        self.trace.as_ref().map(|t| t.to_chrome_trace())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RunStats;

    #[test]
    fn record_step_updates_registry_and_trace() {
        let mut tel = RunTelemetry::new().with_trace();
        let stats = RunStats {
            makespan: 500,
            breakdown: Default::default(),
            hbm_bytes: 4096,
            flops: 0,
            redmule_busy_total: 0,
            spatz_busy_total: 0,
            ops_executed: 0,
        };
        let mut probe = StepProbe::new(4, 2);
        probe.chan_busy[1] = 77;
        probe.mode = StepMode::Memoized;
        tel.on_queued(0, 0);
        tel.on_admitted(0, 0, 0);
        tel.record_step(&StepObs {
            index: 0,
            start: 0,
            end: 500,
            stats: &stats,
            entries: &[(0, 0, true, 96), (1, 1, false, 1)],
            queue_depth: 3,
            pages_in_use: 7,
            slots: 4,
            probe: Some(&probe),
            layer_counts: Some(&[1, 1]),
        });
        tel.on_first_token(0, 500);
        tel.on_completed(0, 900, 0, 500, 5);
        tel.finish_run(900);
        let m = &tel.metrics;
        assert_eq!(m.counter("steps_total"), 1);
        assert_eq!(m.counter("busy_slot_cycles"), 1000);
        assert_eq!(m.counter("slot_cycles"), 2000);
        assert_eq!(m.counter("prefill_entries"), 1);
        assert_eq!(m.counter("decode_entries"), 1);
        assert_eq!(m.counter("engine_steps_memoized_live"), 1);
        assert_eq!(m.gauge("peak_queue_depth"), 3);
        assert_eq!(m.gauge("final_cycles"), 900);
        assert_eq!(m.hbm_chan_busy.totals(), &[0, 77, 0, 0]);
        assert_eq!(m.counter("layered_steps"), 1);
        assert_eq!(m.counter("pipelined_steps"), 1);
        assert_eq!(m.layer_entries.totals(), &[1, 1]);
        assert_eq!(m.hist("ttft_cycles").unwrap().count(), 1);
        assert_eq!(m.hist("tpot_cycles").unwrap().count(), 1);
        let doc = tel.trace_json().unwrap();
        assert!(doc.to_string().contains("prefill"));
        // The deterministic snapshot hides the engine_* section.
        assert!(!tel.snapshot_json().to_string().contains("engine_"));
    }
}
