//! Run-wide observability for serving runs: lifecycle tracing, streaming
//! metrics, and simulator self-profiling.
//!
//! # §Telemetry design
//!
//! ## Event model
//!
//! The scheduler and router narrate a run as a stream of
//! [`LifeEvent`]s anchored on the **virtual clock** (cycles): every request
//! moves `queued → admitted → prefill-chunk×N → decode-step×M → completed`,
//! with `requeue`/`expired` detours carrying cause labels (band death,
//! deadline retry, preemption, pool exhaustion), and the machine lane records
//! one `step` slice per composed batch plus `fault`/`band-dead` instants.
//! The same stream drives both exports: the chrome-trace JSON written by
//! `schedule --trace-out` (requests as pids, phases as slices — see
//! [`events`] for the time-unit convention shared with `sim::trace`) and the
//! lifecycle counters/histograms in the metrics registry.
//!
//! ## Determinism argument
//!
//! Everything in the deterministic snapshot is a pure function of the
//! serving schedule, which PR-7/8's differential walls already pin to be
//! identical across `--threads` and across full-rebuild/incremental/memoized
//! composition. Two details make the *resource* metrics hold to the same
//! standard:
//!
//! - **Busy fractions are occupancy sums, not achieved service.** Summing
//!   `op.occupancy` per resource over the composed program is independent of
//!   the DES's execution order, hence thread-invariant. It also survives
//!   fault derating (we report nominal scheduled demand; the makespan
//!   stretch shows up in the step slices instead).
//! - **Attribution uses stable identities only.** The batch builders
//!   allocate HBM channel resources first, so `ResourceId(c) == channel c` —
//!   exact per-channel totals fall out of the op table. NoC row/col buses
//!   have *no* stable global id across solo-vs-batch composes, so collective
//!   traffic (SumReduce/MaxReduce/Multicast) is attributed per batch *slot*
//!   via the entry spans instead. Both quantities are additive between a
//!   solo-composed entry and the same entry inside a batch (the conservation
//!   property memoization relies on), so the memo path merges per-entry
//!   contributions bit-identically to scanning the full batch program.
//!
//! Counters that describe *how the simulator computed* the run — composer
//! patch/memo hit rates — are mode-dependent by design; they live under the
//! `engine_` prefix and are excluded from the deterministic snapshot
//! ([`metrics::ENGINE_PREFIX`]).
//!
//! ## Why windows, not raw series
//!
//! A 1M-request stream takes millions of steps; storing anything per step
//! (let alone per token) would make observability the biggest allocation in
//! the simulator. Timeseries therefore use [`metrics::WindowSeries`]: at
//! most [`metrics::MAX_WINDOWS`] windows whose length doubles (merging
//! pairwise) when the run outgrows them. Attributing each step's amount to
//! the window containing the step's start commutes with that re-bucketing,
//! so the bounded series stays a deterministic function of the event stream
//! no matter when doublings happen. Histograms are fixed 65-bucket log2
//! (HDR-style); the registry footprint is O(windows + buckets + names) —
//! asserted by the memory-bound test — never O(requests).
//!
//! ## Cost model
//!
//! Telemetry is opt-in per run: the scheduler entry points take
//! `Option<&mut RunTelemetry>`, and `None` (the default path) does no work
//! and no allocation — the composer's probe stays disabled and the only
//! residue is a handful of `is_some()` checks. When on, per-step cost is
//! O(channels + entries) on memoized steps and one O(ops) scan otherwise.
//! Wall-clock phase timers ([`profile`]) are a further opt-in (`--profile`)
//! and are never part of deterministic output.

pub mod events;
pub mod metrics;
pub mod profile;

pub use events::{
    chrome_trace_doc, DropCause, LifeEvent, RequeueCause, TraceCollector, CHROME_DISPLAY_UNIT,
};
pub use metrics::{Hist, LaneSet, MetricsRegistry, WindowSeries, ENGINE_PREFIX, MAX_WINDOWS};
pub use profile::{ProfPhase, Profiler, ALL_PHASES};

use crate::sim::{Cycle, RunStats};
use crate::util::json::Json;

/// How the composer produced a step's stats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepMode {
    /// Composed from scratch and sealed.
    Rebuilt,
    /// Cached sealed program with costs patched in place.
    Patched,
    /// Merged from per-entry solo memo results; no batch program existed.
    Memoized,
}

/// Diagnostics captured on a faulted step (counts plus the DES stall
/// report that previously went only to stderr).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultNote {
    pub killed: u32,
    pub stalled: u32,
    pub detail: String,
}

/// Per-step resource attribution filled in by the `StepComposer` when a
/// telemetry sink is attached. Vectors are preallocated once and reused
/// every step; nothing here allocates on the hot path.
#[derive(Clone, Debug)]
pub struct StepProbe {
    /// Scheduled busy cycles per HBM channel (`ResourceId(c) == channel c`).
    pub chan_busy: Vec<u64>,
    /// Scheduled NoC-collective busy cycles per batch slot.
    pub noc_slot_busy: Vec<u64>,
    pub mode: StepMode,
    pub fault: Option<FaultNote>,
}

impl StepProbe {
    pub fn new(n_chan: usize, slots: usize) -> Self {
        StepProbe {
            chan_busy: vec![0; n_chan],
            noc_slot_busy: vec![0; slots],
            mode: StepMode::Rebuilt,
            fault: None,
        }
    }

    pub fn reset(&mut self) {
        self.chan_busy.iter_mut().for_each(|v| *v = 0);
        self.noc_slot_busy.iter_mut().for_each(|v| *v = 0);
        self.mode = StepMode::Rebuilt;
        self.fault = None;
    }
}

/// Everything the scheduler observes about one composed step, handed to
/// [`RunTelemetry::record_step`].
pub struct StepObs<'a> {
    pub index: u64,
    pub start: Cycle,
    pub end: Cycle,
    pub stats: &'a RunStats,
    /// Per-entry `(slot, request, is_prefill, tokens)` of the step batch.
    pub entries: &'a [(usize, usize, bool, u64)],
    pub queue_depth: u64,
    pub pages_in_use: u64,
    pub slots: u64,
    pub probe: Option<&'a StepProbe>,
}

/// The per-run telemetry sink threaded through `scheduler::simulate` /
/// `scheduler::route`. Metrics are always on once a sink exists; the trace
/// collector and profiler are further opt-ins.
#[derive(Debug, Default)]
pub struct RunTelemetry {
    pub metrics: MetricsRegistry,
    pub trace: Option<TraceCollector>,
    pub profile: Option<Profiler>,
}

impl RunTelemetry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Also collect the lifecycle event stream for a chrome-trace export.
    pub fn with_trace(mut self) -> Self {
        self.trace = Some(TraceCollector::new());
        self
    }

    /// Also collect wall-clock phase timings (enables the global profiling
    /// gate so `Program::seal` reports verify time).
    pub fn with_profile(mut self) -> Self {
        profile::set_profiling(true);
        self.profile = Some(Profiler::new());
        self
    }

    fn event(&mut self, ev: LifeEvent) {
        if let Some(t) = self.trace.as_mut() {
            t.push(ev);
        }
    }

    pub fn on_queued(&mut self, req: usize, t: Cycle) {
        self.metrics.inc("requests_queued", 1);
        self.event(LifeEvent::Queued { req: req as u32, t });
    }

    pub fn on_admitted(&mut self, req: usize, slot: usize, t: Cycle) {
        self.metrics.inc("requests_admitted", 1);
        self.event(LifeEvent::Admitted { req: req as u32, slot: slot as u32, t });
    }

    pub fn on_first_token(&mut self, req: usize, t: Cycle) {
        self.event(LifeEvent::FirstToken { req: req as u32, t });
    }

    /// One output token produced (first or decode).
    pub fn on_token(&mut self) {
        self.metrics.inc("tokens_generated", 1);
    }

    /// Completion with final per-request metrics (matches `RequestMetrics`
    /// semantics: TTFT from arrival, TPOT over `output - 1` decode tokens).
    pub fn on_completed(
        &mut self,
        req: usize,
        t: Cycle,
        arrival: Cycle,
        first: Cycle,
        output: u64,
    ) {
        self.metrics.inc("requests_completed", 1);
        self.metrics.observe("ttft_cycles", first.saturating_sub(arrival));
        if output > 1 {
            self.metrics.observe("tpot_cycles", t.saturating_sub(first) / (output - 1));
        }
        self.event(LifeEvent::Completed { req: req as u32, t });
    }

    pub fn on_requeued(&mut self, req: usize, t: Cycle, cause: RequeueCause) {
        self.metrics.inc(
            match cause {
                RequeueCause::BandDeath => "requeue_band_death",
                RequeueCause::DeadlineRetry => "requeue_deadline_retry",
                RequeueCause::Preemption => "requeue_preemption",
            },
            1,
        );
        self.event(LifeEvent::Requeued { req: req as u32, t, cause });
    }

    pub fn on_dropped(&mut self, req: usize, t: Cycle, cause: DropCause) {
        self.metrics.inc("requests_expired", 1);
        self.event(LifeEvent::Dropped { req: req as u32, t, cause });
    }

    pub fn on_band_dead(&mut self, slot: usize, t: Cycle) {
        self.metrics.inc("bands_died", 1);
        self.event(LifeEvent::BandDead { slot: slot as u32, t });
    }

    /// Sample one composed step into the registry (and the trace, if on).
    pub fn record_step(&mut self, obs: &StepObs) {
        let t0 = self.profile.as_ref().map(|_| std::time::Instant::now());
        let mk = obs.end.saturating_sub(obs.start);
        let m = &mut self.metrics;
        m.inc("steps_total", 1);
        m.inc("hbm_bytes_total", obs.stats.hbm_bytes);
        m.inc("busy_slot_cycles", obs.entries.len() as u64 * mk);
        m.inc("slot_cycles", obs.slots * mk);
        m.observe("step_makespan_cycles", mk);
        m.observe("queue_depth", obs.queue_depth);
        m.observe("batch_entries", obs.entries.len() as u64);
        m.observe("pages_in_use", obs.pages_in_use);
        m.gauge_max("peak_queue_depth", obs.queue_depth);
        m.gauge_max("peak_pages_in_use", obs.pages_in_use);
        m.series_add("busy_slot_cycles", obs.start, obs.entries.len() as u64 * mk);
        m.series_add("slot_cycles", obs.start, obs.slots * mk);
        m.series_add("hbm_bytes", obs.start, obs.stats.hbm_bytes);
        let mut tokens = 0u64;
        for &(_, _, is_prefill, len) in obs.entries {
            if is_prefill {
                m.inc("prefill_entries", 1);
                m.inc("prefill_tokens", len);
            } else {
                m.inc("decode_entries", 1);
                tokens += 1;
            }
        }
        m.series_add("decode_tokens", obs.start, tokens);
        if let Some(p) = obs.probe {
            m.hbm_chan_busy.add(obs.start, &p.chan_busy);
            m.noc_slot_busy.add(obs.start, &p.noc_slot_busy);
            match p.mode {
                StepMode::Rebuilt => m.inc("engine_steps_rebuilt", 1),
                StepMode::Patched => m.inc("engine_steps_patched_live", 1),
                StepMode::Memoized => m.inc("engine_steps_memoized_live", 1),
            }
            if let Some(f) = &p.fault {
                m.inc("steps_faulted", 1);
                m.inc("ops_killed", f.killed as u64);
                m.inc("ops_stalled", f.stalled as u64);
                if self.trace.is_some() {
                    let ev = LifeEvent::Fault {
                        t: obs.start,
                        killed: f.killed,
                        stalled: f.stalled,
                        detail: f.detail.clone(),
                    };
                    self.event(ev);
                }
            }
        }
        if self.trace.is_some() {
            let step = LifeEvent::Step {
                index: obs.index,
                start: obs.start,
                end: obs.end,
                entries: obs.entries.len() as u32,
                hbm_bytes: obs.stats.hbm_bytes,
            };
            self.event(step);
            for &(_, req, is_prefill, len) in obs.entries {
                self.event(LifeEvent::Slice {
                    req: req as u32,
                    prefill: is_prefill,
                    tokens: len,
                    start: obs.start,
                    end: obs.end,
                });
            }
        }
        if let (Some(p), Some(t0)) = (self.profile.as_mut(), t0) {
            p.add_nanos(ProfPhase::Metrics, t0.elapsed().as_nanos() as u64);
        }
    }

    /// Final-clock bookkeeping once the run loop exits.
    pub fn finish_run(&mut self, clock: Cycle) {
        self.metrics.gauge_set("final_cycles", clock);
    }

    pub fn merge_profile(&mut self, other: &Profiler) {
        if let Some(p) = self.profile.as_mut() {
            p.merge(other);
        }
    }

    /// Deterministic JSON snapshot (the block embedded in `ServingReport`).
    pub fn snapshot_json(&self) -> Json {
        self.metrics.to_json(false)
    }

    /// Chrome-trace document of the collected lifecycle stream, if tracing.
    pub fn trace_json(&self) -> Option<Json> {
        self.trace.as_ref().map(|t| t.to_chrome_trace())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RunStats;

    #[test]
    fn record_step_updates_registry_and_trace() {
        let mut tel = RunTelemetry::new().with_trace();
        let stats = RunStats {
            makespan: 500,
            breakdown: Default::default(),
            hbm_bytes: 4096,
            flops: 0,
            redmule_busy_total: 0,
            spatz_busy_total: 0,
            ops_executed: 0,
        };
        let mut probe = StepProbe::new(4, 2);
        probe.chan_busy[1] = 77;
        probe.mode = StepMode::Memoized;
        tel.on_queued(0, 0);
        tel.on_admitted(0, 0, 0);
        tel.record_step(&StepObs {
            index: 0,
            start: 0,
            end: 500,
            stats: &stats,
            entries: &[(0, 0, true, 96), (1, 1, false, 1)],
            queue_depth: 3,
            pages_in_use: 7,
            slots: 4,
            probe: Some(&probe),
        });
        tel.on_first_token(0, 500);
        tel.on_completed(0, 900, 0, 500, 5);
        tel.finish_run(900);
        let m = &tel.metrics;
        assert_eq!(m.counter("steps_total"), 1);
        assert_eq!(m.counter("busy_slot_cycles"), 1000);
        assert_eq!(m.counter("slot_cycles"), 2000);
        assert_eq!(m.counter("prefill_entries"), 1);
        assert_eq!(m.counter("decode_entries"), 1);
        assert_eq!(m.counter("engine_steps_memoized_live"), 1);
        assert_eq!(m.gauge("peak_queue_depth"), 3);
        assert_eq!(m.gauge("final_cycles"), 900);
        assert_eq!(m.hbm_chan_busy.totals(), &[0, 77, 0, 0]);
        assert_eq!(m.hist("ttft_cycles").unwrap().count(), 1);
        assert_eq!(m.hist("tpot_cycles").unwrap().count(), 1);
        let doc = tel.trace_json().unwrap();
        assert!(doc.to_string().contains("prefill"));
        // The deterministic snapshot hides the engine_* section.
        assert!(!tel.snapshot_json().to_string().contains("engine_"));
    }
}
