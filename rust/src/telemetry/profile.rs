//! Simulator self-profiling: wall-clock phase timers behind `--profile`.
//!
//! Unlike everything else in `telemetry`, this reads the host clock — so it
//! is kept strictly out of the deterministic exports and exists only to show
//! where the *simulator* spends real time (compose / patch / seal / verify /
//! execute / metrics), per step, so perf work knows which lever to pull.
//!
//! Verification happens inside `Program::seal`, which has no profiler in
//! scope; it reports through a process-global gate ([`set_profiling`]) and a
//! thread-local accumulator that the composer drains right after sealing and
//! subtracts from the seal phase. When profiling is off the gate is a single
//! relaxed atomic load and no `Instant` is ever taken.

use std::cell::Cell;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// One simulator phase on the per-step cost table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProfPhase {
    /// Emitting batch/solo programs into the arena.
    Compose,
    /// Incremental cost-patching of the cached sealed program.
    Patch,
    /// `Program::seal` (dependents/shard CSR derivation), minus verify.
    Seal,
    /// Structural verification inside seal (debug builds or `--verify`).
    Verify,
    /// Discrete-event execution of the sealed program.
    Execute,
    /// Telemetry sampling itself (registry updates, trace events).
    Metrics,
}

/// Every profiled phase, in report order.
pub const ALL_PHASES: [ProfPhase; 6] = [
    ProfPhase::Compose,
    ProfPhase::Patch,
    ProfPhase::Seal,
    ProfPhase::Verify,
    ProfPhase::Execute,
    ProfPhase::Metrics,
];

impl ProfPhase {
    /// Stable lowercase name.
    pub fn label(self) -> &'static str {
        match self {
            ProfPhase::Compose => "compose",
            ProfPhase::Patch => "patch",
            ProfPhase::Seal => "seal",
            ProfPhase::Verify => "verify",
            ProfPhase::Execute => "execute",
            ProfPhase::Metrics => "metrics",
        }
    }
}

static PROFILING: AtomicBool = AtomicBool::new(false);

thread_local! {
    static VERIFY_NANOS: Cell<u64> = const { Cell::new(0) };
}

/// Enable the process-global profiling gate (sticky; cheap relaxed load when
/// off is the only cost paid by non-profiled runs).
pub fn set_profiling(on: bool) {
    PROFILING.store(on, Ordering::Relaxed);
}

/// True while self-profiling is globally enabled.
pub fn profiling() -> bool {
    PROFILING.load(Ordering::Relaxed)
}

/// Start timing a verification pass, if profiling is on. Called from
/// `Program::seal`'s verify site.
pub fn verify_timer() -> Option<Instant> {
    if profiling() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Record a finished verification pass into the thread-local accumulator.
pub fn verify_done(t: Option<Instant>) {
    if let Some(t) = t {
        let ns = t.elapsed().as_nanos() as u64;
        VERIFY_NANOS.with(|c| c.set(c.get() + ns));
    }
}

/// Drain the thread-local verify accumulator (returns nanos since last take).
pub fn take_verify_nanos() -> u64 {
    VERIFY_NANOS.with(|c| c.replace(0))
}

/// Accumulated wall-clock cost per phase.
#[derive(Clone, Debug, Default)]
pub struct Profiler {
    nanos: [u64; ALL_PHASES.len()],
    calls: [u64; ALL_PHASES.len()],
}

impl Profiler {
    /// A zeroed profiler.
    pub fn new() -> Self {
        Self::default()
    }

    fn idx(phase: ProfPhase) -> usize {
        ALL_PHASES.iter().position(|&p| p == phase).unwrap()
    }

    /// Add one lap to a phase.
    pub fn add_nanos(&mut self, phase: ProfPhase, nanos: u64) {
        let i = Self::idx(phase);
        self.nanos[i] += nanos;
        self.calls[i] += 1;
    }

    /// Accumulated wall-clock of a phase.
    pub fn nanos(&self, phase: ProfPhase) -> u64 {
        self.nanos[Self::idx(phase)]
    }

    /// Fold another profiler's laps into this one.
    pub fn merge(&mut self, other: &Profiler) {
        for i in 0..ALL_PHASES.len() {
            self.nanos[i] += other.nanos[i];
            self.calls[i] += other.calls[i];
        }
    }

    /// Wall-clock summed over every phase.
    pub fn total_nanos(&self) -> u64 {
        self.nanos.iter().sum()
    }

    /// Render the per-step cost table printed under `--profile`.
    pub fn render(&self, steps: u64) -> String {
        let total = self.total_nanos().max(1);
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:<10} {:>12} {:>10} {:>14} {:>7}",
            "phase", "total_ms", "calls", "ns/step", "share"
        );
        for (i, phase) in ALL_PHASES.iter().enumerate() {
            let _ = writeln!(
                out,
                "{:<10} {:>12.3} {:>10} {:>14} {:>6.1}%",
                phase.label(),
                self.nanos[i] as f64 / 1e6,
                self.calls[i],
                self.nanos[i] / steps.max(1),
                100.0 * self.nanos[i] as f64 / total as f64,
            );
        }
        let _ = writeln!(
            out,
            "{:<10} {:>12.3} {:>10} {:>14}",
            "total",
            self.total_nanos() as f64 / 1e6,
            "",
            self.total_nanos() / steps.max(1),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiler_accumulates_and_renders() {
        let mut p = Profiler::new();
        p.add_nanos(ProfPhase::Compose, 1_000_000);
        p.add_nanos(ProfPhase::Execute, 3_000_000);
        let mut q = Profiler::new();
        q.add_nanos(ProfPhase::Execute, 1_000_000);
        p.merge(&q);
        assert_eq!(p.nanos(ProfPhase::Execute), 4_000_000);
        assert_eq!(p.total_nanos(), 5_000_000);
        let table = p.render(10);
        for ph in ALL_PHASES {
            assert!(table.contains(ph.label()), "missing {}", ph.label());
        }
        assert!(table.contains("total"));
    }

    #[test]
    fn verify_accumulator_gated_on_global_flag() {
        set_profiling(false);
        assert!(verify_timer().is_none());
        verify_done(None);
        assert_eq!(take_verify_nanos(), 0);
        set_profiling(true);
        let t = verify_timer();
        assert!(t.is_some());
        verify_done(t);
        // Elapsed is tiny but the accumulator must have been touched
        // exactly once and then drained.
        let _ = take_verify_nanos();
        assert_eq!(take_verify_nanos(), 0);
        set_profiling(false);
    }
}
