//! `flatattention` — CLI for the FlatAttention reproduction stack.
//!
//! Subcommands:
//!   report <fig3|fig4|fig5a|fig5b|fig5c|table1|table2|section2|area|headline|all>
//!       Regenerate a paper table/figure. Options: --quick, --threads N,
//!       --out results.json
//!   run       Run a single experiment: --dataflow, --seq, --d, --heads,
//!             --batch, --group, --arch <table1|table2-16|table2-8|swcoll>
//!   sweep     Group-size sweep for one workload: --seq/--d/--heads/--batch
//!   validate  Functional validation: group dataflow vs golden attention,
//!             native and (if artifacts exist) PJRT backends
//!   info      Print architecture presets and environment

use std::path::PathBuf;

use flatattention::arch::{presets, ArchConfig};
use flatattention::coordinator::{
    best_group, run_one, set_engine_threads, valid_groups, ExperimentSpec, ResultStore,
};
use flatattention::dataflow::{Dataflow, FlatTiling, Phase, WeightResidency, Workload};
use flatattention::functional::{attention_golden, run_flat_group_functional, NativeCompute};
#[cfg(feature = "pjrt")]
use flatattention::functional::RuntimeCompute;
use flatattention::report::{self, ReportOpts};
use flatattention::runtime::{artifacts_available, default_artifact_dir};
use flatattention::scheduler::batch::validate_slots;
use flatattention::scheduler::{
    try_route, try_route_with, try_simulate, try_simulate_with, BatchPolicy, PagePlacement,
    RequestTrace, RouterConfig, SchedulerConfig, VictimPolicy,
};
use flatattention::sim::FaultPlan;
use flatattention::telemetry::RunTelemetry;
#[cfg(feature = "pjrt")]
use flatattention::runtime::Runtime;
use flatattention::util::cli::{parse, Args};
use flatattention::util::{pool, Rng, Tensor};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match parse(
        &raw,
        &["quick", "help", "pjrt-only", "causal", "decode", "static", "verify", "profile"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.positional.is_empty() {
        print_usage();
        return;
    }
    // --verify: re-run the structural verifier on every sealed program in
    // release builds too (debug builds always verify at seal time).
    if args.flag("verify") {
        flatattention::analysis::set_release_verify(true);
    }
    let cmd = args.positional[0].clone();
    let code = match cmd.as_str() {
        "report" => cmd_report(&args),
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "schedule" => cmd_schedule(&args),
        "validate" => cmd_validate(&args),
        "lint" => cmd_lint(&args),
        "trace" => cmd_trace(&args),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command '{other}'");
            print_usage();
            1
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "flatattention — FlatAttention dataflow + fabric collectives co-optimization (reproduction)

USAGE:
  flatattention report <fig3|fig4|fig5a|fig5b|fig5c|table1|table2|section2|area|headline|ablations|serving|schedule|robustness|telemetry|layers|all>
                      [--quick] [--threads N] [--out results.json]
  flatattention run    --dataflow <fa2|fa3|flat|flatcoll|flatasyn> [--seq 4096] [--d 128]
                      [--heads 32] [--batch 2] [--group 32] [--arch table1] [--threads N]
                      (--threads shards the DES event loop; results are bit-identical)
  flatattention sweep  [--seq 4096] [--d 128] [--heads 32] [--batch 2] [--dataflow flatasyn]
  flatattention schedule [--trace builtin|burst|synthetic:N[:GAP]|FILE.csv] [--dataflow all]
                      [--slots 4] [--chunk 512] [--page-tokens 64]
                      [--placement affine|rr|random] [--group G] [--window W] [--static]
                      [--threads N] [--arch table1]
                      (continuous batching of a mixed prefill+decode request trace;
                       CSV rows: arrival,prompt,output[,kv_heads]; synthetic:N streams N
                       recurring-shape requests GAP cycles apart — scales to millions)
                      Router options (any engages the graceful-degradation router):
                      [--faults SPEC] [--deadline CYC] [--retries N] [--max-batch-tokens N]
                      [--max-pages N] [--preemption on|off]
                      [--victim newest|fewest-pages|most-remaining]
                      SPEC: ';'-separated off:CH@F-U | slow:CH@F-UxN[/D] | noc@F-UxN[/D]
                      | die:TILE@AT  (e.g. \"slow:8@0-4000000x4;die:60@1200000\")
                      Layer serving (full transformer layers per step):
                      [--layers L] [--ffn-mult M] [--weights hbm|resident]
                      (--ffn-mult >= 1 appends each request's out-proj/FFN/QKV
                       GEMM tail to its band; --layers L > 1 runs L layers per
                       token, pipelining requests at different layer depths
                       across bands; --weights picks streamed vs resident
                       projection/FFN weights. Plain `schedule` only — the
                       router serves attention-only steps)
                      Telemetry (needs a single --dataflow, not 'all'):
                      [--trace-out FILE]    request-lifecycle chrome-trace JSON
                                            (open in chrome://tracing or Perfetto)
                      [--metrics-out FILE]  Prometheus text snapshot of the run metrics
                      [--profile]           wall-clock phase table (compose/patch/seal/
                                            verify/execute/metrics) on stdout
                      `report telemetry` renders utilization-over-time + lifecycle
                      waterfall tables for a canned fault-injected router run
  flatattention validate [--seq 256] [--d 64] [--group 4] [--pjrt-only]
  flatattention lint   [--quick]   (structural verifier + roofline cross-check sweep:
                      dataflows x presets x fold modes x paged batches x fault plans)
  flatattention trace  [run options] [--tiles 64] --out trace.json   (chrome://tracing)
  flatattention info

Global: --verify   re-run the structural program verifier on every sealed
                   program in release builds (debug builds always verify);
                   `run --verify` also cross-checks the makespan against the
                   analytical roofline lower bounds

Architectures: --arch <table1|swcoll|table2-32|table2-16|table2-8> or --arch-file configs/foo.toml
Workloads: --seq S --d D --heads H --batch B [--causal] [--kv-heads K] [--decode] [--window W]
  --kv-heads K   GQA/MQA: K K/V heads shared by H query heads (K divides H)
  --decode       single-token decode against an S-long KV cache (else prefill)
  --window W     sliding-window attention over the last W positions (implies --causal)"
    );
}

fn opts_from(args: &Args) -> ReportOpts {
    ReportOpts {
        threads: args.get_usize("threads", pool::default_threads()).unwrap_or(4),
        quick: args.flag("quick"),
    }
}

fn arch_from(args: &Args) -> Result<ArchConfig, String> {
    if let Some(path) = args.get("arch-file") {
        return flatattention::arch::load_arch(std::path::Path::new(path))
            .map_err(|e| e.to_string());
    }
    match args.get_or("arch", "table1") {
        "table1" | "best" => Ok(presets::table1()),
        "swcoll" => Ok(presets::table1_sw_collectives()),
        "table2-32" => Ok(presets::table2(32)),
        "table2-16" => Ok(presets::table2(16)),
        "table2-8" => Ok(presets::table2(8)),
        other => Err(format!("unknown arch '{other}'")),
    }
}

fn workload_from(args: &Args) -> Result<Workload, String> {
    let seq = args.get_u64("seq", 4096)?;
    let d = args.get_u64("d", 128)?;
    let heads = args.get_u64("heads", 32)?;
    let batch = args.get_u64("batch", 2)?;
    let kv_heads = args.get_u64("kv-heads", heads)?;
    if seq == 0 || d == 0 || heads == 0 || batch == 0 {
        return Err(format!(
            "workload dims must be non-zero (--seq {seq} --d {d} --heads {heads} --batch {batch})"
        ));
    }
    if kv_heads == 0 || kv_heads > heads || heads % kv_heads != 0 {
        return Err(format!(
            "--kv-heads {kv_heads} must divide --heads {heads} (GQA groups must be uniform)"
        ));
    }
    let mut wl = Workload::new(seq, d, heads, batch)
        .with_causal(args.flag("causal"))
        .with_kv_heads(kv_heads);
    if args.flag("decode") {
        wl = wl.with_phase(Phase::Decode);
    }
    let window = args.get_u64("window", 0)?;
    if window > 0 {
        wl = wl.with_window(window);
    }
    Ok(wl)
}

fn cmd_report(args: &Args) -> i32 {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let opts = opts_from(args);
    let mut store = ResultStore::new();
    let all = which == "all";
    if all || which == "table1" {
        println!("{}", report::tables::render_table1());
    }
    if all || which == "table2" {
        println!("{}", report::tables::render_table2());
    }
    if all || which == "section2" {
        println!("{}", report::section2::render_section2());
    }
    if all || which == "area" {
        println!("{}", report::section2::render_area());
    }
    if all || which == "fig3" {
        println!("{}", report::fig3::render(&opts, Some(&mut store)));
    }
    if all || which == "fig4" {
        println!("{}", report::fig4::render(&opts, Some(&mut store)));
    }
    if all || which == "fig5a" {
        println!("{}", report::fig5a::render(&opts, Some(&mut store)));
    }
    if all || which == "fig5b" {
        println!("{}", report::fig5b::render(&opts, Some(&mut store)));
    }
    if all || which == "fig5c" {
        println!("{}", report::fig5c::render(&opts, Some(&mut store)));
    }
    if all || which == "headline" {
        println!("{}", report::headline::render(&opts, Some(&mut store)));
    }
    if all || which == "ablations" {
        println!("{}", report::ablations::render(&opts, Some(&mut store)));
    }
    if all || which == "serving" {
        println!("{}", report::serving::render(&opts, Some(&mut store)));
    }
    if all || which == "schedule" {
        println!("{}", report::schedule::render(&opts, Some(&mut store)));
    }
    if all || which == "robustness" {
        println!("{}", report::robustness::render(&opts, Some(&mut store)));
    }
    if all || which == "telemetry" {
        println!("{}", report::telemetry::render(&opts, Some(&mut store)));
    }
    if all || which == "layers" {
        println!("{}", report::layers::render(&opts, Some(&mut store)));
    }
    if !matches!(
        which,
        "all" | "table1" | "table2" | "section2" | "area" | "fig3" | "fig4" | "fig5a" | "fig5b"
            | "fig5c" | "headline" | "ablations" | "serving" | "schedule" | "robustness"
            | "telemetry" | "layers"
    ) {
        eprintln!("unknown report '{which}'");
        return 1;
    }
    if let Some(out) = args.get("out") {
        match store.save(&PathBuf::from(out)) {
            Ok(()) => println!("wrote {out}"),
            Err(e) => {
                eprintln!("error writing {out}: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_run(args: &Args) -> i32 {
    let arch = match arch_from(args) {
        Ok(a) => a,
        Err(e) => return fail(&e),
    };
    let workload = match workload_from(args) {
        Ok(w) => w,
        Err(e) => return fail(&e),
    };
    let df_label = args.get_or("dataflow", "flatasyn");
    let Some(dataflow) = Dataflow::from_label(df_label) else {
        return fail(&format!("unknown dataflow '{df_label}'"));
    };
    let group = args.get_usize("group", arch.mesh_x.min(32)).unwrap_or(32);
    // DES workers for this one experiment (sharded executor;
    // bit-identical results at every count — wall-clock knob only).
    let threads = args.get_usize("threads", 1).unwrap_or(1);
    set_engine_threads(threads);
    let spec = ExperimentSpec { arch: arch.clone(), workload, dataflow, group };
    let r = run_one(&spec);
    println!("{}", spec.id());
    if dataflow.is_flat() {
        let t = FlatTiling::resolve(&arch, &workload, group, dataflow == Dataflow::FlatAsyn);
        println!(
            "tiling: slice {}x{} per tile, block {}, T_r {}, T_c {}, {} group(s), \
             {} head(s)/stack x {} chunk(s)",
            t.slice, t.slice, t.block, t.t_r, t.t_c, t.num_groups, t.share, t.chunks
        );
    }
    println!(
        "runtime {:.3} ms ({} cycles), utilization {:.1}%, RedMulE-active {:.1}%, HBM {:.2} GB ({:.1}% BW), {:.0} TFLOPS",
        r.runtime_ms,
        r.makespan,
        r.utilization * 100.0,
        r.redmule_active_util * 100.0,
        r.hbm_bytes as f64 / 1e9,
        r.hbm_bw_util * 100.0,
        r.tflops
    );
    println!("breakdown: {}", r.breakdown.to_json().to_string());
    if args.flag("verify") {
        // Cross-check the reported makespan against the analytical roofline
        // (run_one memoizes stats only, so rebuild the program for the
        // occupancy-sum bounds). Tile deaths remove work and invalidate the
        // lower bounds, so an active killing fault plan skips the check —
        // see the `analysis` module essay.
        let kills =
            flatattention::coordinator::fault_plan().is_some_and(|p| !p.deaths.is_empty());
        if kills {
            println!("roofline: skipped (active fault plan kills tiles)");
        } else {
            let mut p =
                flatattention::dataflow::build_program(&arch, &workload, dataflow, group);
            p.seal();
            let rl = flatattention::analysis::Roofline::of(&arch, &workload, &p);
            match rl.check(r.makespan) {
                Ok(rep) => println!(
                    "roofline: {} bound {} cycles, utilization {:.1}%",
                    rep.binding,
                    rep.bound,
                    rep.utilization * 100.0
                ),
                Err(d) => return fail(&d.to_string()),
            }
        }
    }
    0
}

fn cmd_sweep(args: &Args) -> i32 {
    let arch = match arch_from(args) {
        Ok(a) => a,
        Err(e) => return fail(&e),
    };
    let workload = match workload_from(args) {
        Ok(w) => w,
        Err(e) => return fail(&e),
    };
    let df_label = args.get_or("dataflow", "flatasyn");
    let Some(dataflow) = Dataflow::from_label(df_label) else {
        return fail(&format!("unknown dataflow '{df_label}'"));
    };
    if !dataflow.is_flat() {
        return fail("sweep requires a FlatAttention dataflow");
    }
    let threads = args.get_usize("threads", pool::default_threads()).unwrap_or(4);
    println!("group sweep for {} on {}:", workload.label(), arch.name);
    for g in valid_groups(&arch) {
        let spec = ExperimentSpec { arch: arch.clone(), workload, dataflow, group: g };
        let r = run_one(&spec);
        println!(
            "  {g:>2}x{g:<2}  {:>10.3} ms  util {:>5.1}%  active {:>5.1}%  HBM {:>6.2} GB",
            r.runtime_ms,
            r.utilization * 100.0,
            r.redmule_active_util * 100.0,
            r.hbm_bytes as f64 / 1e9
        );
    }
    let best = best_group(&arch, &workload, dataflow, threads);
    println!("best: {0}x{0} ({1:.3} ms)", best.group, best.runtime_ms);
    0
}

fn cmd_schedule(args: &Args) -> i32 {
    let arch = match arch_from(args) {
        Ok(a) => a,
        Err(e) => return fail(&e),
    };
    let heads = args.get_u64("heads", 32).unwrap_or(32);
    let head_dim = args.get_u64("d", 128).unwrap_or(128);
    let kv_default = args
        .get_u64("kv-heads", if heads % 8 == 0 { 8 } else { heads })
        .unwrap_or(heads);
    if heads == 0 || head_dim == 0 || kv_default == 0 || heads % kv_default != 0 {
        return fail(&format!(
            "--kv-heads {kv_default} must divide --heads {heads} (both non-zero)"
        ));
    }
    let trace_arg = args.get_or("trace", "builtin");
    let trace = if let Some(spec) = trace_arg.strip_prefix("synthetic:") {
        // `synthetic:N[:GAP]` — the deterministic recurring-shape stream
        // (scheduler::RequestTrace::synthetic); the million-request-scale
        // replay path the bench gates.
        let mut parts = spec.splitn(2, ':');
        let n = parts.next().and_then(|s| s.parse::<usize>().ok());
        let gap = match parts.next() {
            Some(g) => g.parse::<u64>().ok(),
            None => Some(1_000),
        };
        match (n, gap) {
            (Some(n), Some(gap)) if n > 0 => RequestTrace::synthetic(n, gap),
            _ => return fail(&format!("--trace {trace_arg}: expected synthetic:N[:GAP], N >= 1")),
        }
    } else {
        match RequestTrace::builtin(trace_arg, kv_default) {
            Some(t) => t,
            None => match std::fs::read_to_string(trace_arg) {
                Ok(text) => match RequestTrace::parse(&text, kv_default) {
                    Ok(t) => t,
                    Err(e) => return fail(&format!("parsing trace {trace_arg}: {e}")),
                },
                Err(e) => {
                    return fail(&format!(
                        "--trace {trace_arg}: not a builtin trace (builtin|mixed|burst), not \
                         synthetic:N[:GAP], and not a readable file ({e})"
                    ))
                }
            },
        }
    };
    let slots = args.get_usize("slots", 4).unwrap_or(4);
    // Slot geometry alone first (group-agnostic: Flash2 ignores it).
    if let Err(e) = validate_slots(&arch, slots, 1, Dataflow::Flash2) {
        return fail(&e);
    }
    let rows_per = arch.mesh_y / slots;
    let default_group = [8usize, 4, 2, 1]
        .into_iter()
        .find(|g| rows_per % g == 0 && arch.mesh_x % g == 0)
        .unwrap_or(1);
    let group = args.get_usize("group", default_group).unwrap_or(default_group);
    // Full band/group geometry as the scheduler itself will check it.
    if let Err(e) = validate_slots(&arch, slots, group, Dataflow::FlatColl) {
        return fail(&e);
    }
    let chunk = args.get_u64("chunk", 512).unwrap_or(512);
    let page_tokens = args.get_u64("page-tokens", 64).unwrap_or(64);
    if chunk == 0 || page_tokens == 0 {
        return fail("--chunk and --page-tokens must be >= 1");
    }
    let placement_arg = args.get_or("placement", "affine");
    let Some(placement) = PagePlacement::from_label(placement_arg) else {
        return fail(&format!(
            "unknown --placement '{placement_arg}' (affine|rr|round-robin|random)"
        ));
    };
    let window = args.get_u64("window", 0).unwrap_or(0);
    let policy = if args.flag("static") { BatchPolicy::Static } else { BatchPolicy::Continuous };

    // Layer serving: --ffn-mult >= 1 turns each step into a full
    // transformer layer (attention + GEMM tails); --layers L runs L of
    // them per token. Combination validity is checked by the scheduler
    // (`ScheduleError::BadLayers`).
    let layers = args.get_usize("layers", 1).unwrap_or(1);
    let ffn_mult = args.get_u64("ffn-mult", 0).unwrap_or(0);
    let weights_arg = args.get_or("weights", "hbm");
    let Some(weights) = WeightResidency::from_label(weights_arg) else {
        return fail(&format!("unknown --weights '{weights_arg}' (hbm|resident)"));
    };

    // Router options: providing any of them runs the request-lifecycle
    // router (admission budgets, deadlines, preemption, fault remapping)
    // instead of the plain scheduler.
    let router_keys =
        ["faults", "deadline", "retries", "max-batch-tokens", "max-pages", "preemption", "victim"];
    let use_router = router_keys.iter().any(|k| args.get(k).is_some());
    let faults = match args.get("faults") {
        Some(spec) => match FaultPlan::parse(spec) {
            Ok(p) => p,
            Err(e) => return fail(&format!("--faults: {e}")),
        },
        None => FaultPlan::none(),
    };
    let preemption = match args.get_or("preemption", "on") {
        "on" | "true" => true,
        "off" | "false" => false,
        other => return fail(&format!("--preemption '{other}': expected on|off")),
    };
    let victim_arg = args.get_or("victim", "fewest-pages");
    let Some(victim) = VictimPolicy::from_label(victim_arg) else {
        return fail(&format!(
            "unknown --victim '{victim_arg}' (newest|fewest-pages|most-remaining)"
        ));
    };
    let router_cfg = use_router.then(|| RouterConfig {
        faults,
        max_batch_total_tokens: args.get_u64("max-batch-tokens", 0).unwrap_or(0),
        max_total_pages: args.get_u64("max-pages", 0).unwrap_or(0),
        deadline: args.get_u64("deadline", 0).unwrap_or(0),
        max_retries: args.get_usize("retries", 1).unwrap_or(1),
        victim,
        preemption,
    });

    // Telemetry exports: any of these attaches a per-run sink (metrics
    // registry + optional lifecycle trace / phase profiler) to the run.
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_out = args.get("metrics-out").map(str::to_string);
    let profile = args.flag("profile");
    let telemetry_on = trace_out.is_some() || metrics_out.is_some() || profile;

    let df_arg = args.get_or("dataflow", "all");
    let dataflows: Vec<Dataflow> = if df_arg == "all" {
        flatattention::dataflow::ALL_DATAFLOWS.to_vec()
    } else {
        match Dataflow::from_label(df_arg) {
            Some(df) => vec![df],
            None => return fail(&format!("unknown dataflow '{df_arg}'")),
        }
    };
    if telemetry_on && dataflows.len() != 1 {
        return fail("--trace-out/--metrics-out/--profile need a single --dataflow (not 'all')");
    }

    println!(
        "serving schedule on {}: {} requests, slots={slots}, chunk={chunk}, pages={page_tokens} \
         tok, placement={}, {}{}",
        arch.name,
        trace.requests.len(),
        placement.label(),
        if policy == BatchPolicy::Static { "static batching" } else { "continuous batching" },
        if window > 0 { format!(", window={window}") } else { String::new() },
    );
    if ffn_mult > 0 {
        println!(
            "layer serving: {layers} layer(s)/token, FFN x{ffn_mult}, weights {}",
            weights.label()
        );
    }
    if let Some(rc) = &router_cfg {
        if policy == BatchPolicy::Static {
            return fail("--static is not supported with router options (continuous only)");
        }
        let fault_desc = if rc.faults.is_none() {
            "none".to_string()
        } else {
            format!("{:#x}", rc.faults.fingerprint())
        };
        println!(
            "router: faults={}, deadline={}, retries={}, max-batch-tokens={}, max-pages={}, \
             preemption={}, victim={}",
            fault_desc,
            rc.deadline,
            rc.max_retries,
            rc.max_batch_total_tokens,
            rc.max_total_pages,
            if rc.preemption { "on" } else { "off" },
            rc.victim.label()
        );
        println!(
            "{:>9}  {:>10}  {:>10}  {:>9}  {:>9}  {:>9}  {:>9}  {:>4}  {:>4}  {:>5}  {:>5}",
            "dataflow",
            "tokens/s",
            "goodput/s",
            "TTFT_p50",
            "TTFT_p95",
            "TTFT_p99",
            "TPOT_p95",
            "done",
            "exp",
            "pre",
            "dead"
        );
    } else {
        println!(
            "{:>9}  {:>10}  {:>10}  {:>9}  {:>9}  {:>9}  {:>9}  {:>9}  {:>8}  {:>6}",
            "dataflow",
            "tokens/s",
            "goodput/s",
            "TTFT_ms",
            "TTFT_p95",
            "TPOT_ms",
            "TPOT_p95",
            "occup",
            "HBM_GB",
            "steps"
        );
    }
    for df in dataflows {
        let mut cfg = SchedulerConfig::new(df);
        cfg.group = group;
        cfg.slots = slots;
        cfg.chunk = chunk;
        cfg.page_tokens = page_tokens;
        cfg.placement = placement;
        cfg.policy = policy;
        cfg.heads = heads;
        cfg.head_dim = head_dim;
        cfg.window = window;
        cfg.layers = layers;
        cfg.ffn_mult = ffn_mult;
        cfg.weights = weights;
        cfg.threads = args.get_usize("threads", 1).unwrap_or(1);
        let mut tel = if telemetry_on {
            let mut t = RunTelemetry::new();
            if trace_out.is_some() {
                t = t.with_trace();
            }
            if profile {
                t = t.with_profile();
            }
            Some(t)
        } else {
            None
        };
        let steps = if let Some(rc) = &router_cfg {
            // Invalid configs surface as one clean diagnostic + exit 1
            // (no panic backtrace), pinned by tests/cli_integration.rs.
            let r = match try_route_with(&arch, &trace, &cfg, rc, tel.as_mut()) {
                Ok(r) => r,
                Err(e) => return fail(&e.to_string()),
            };
            println!(
                "{:>9}  {:>10.0}  {:>10.0}  {:>9.3}  {:>9.3}  {:>9.3}  {:>9.4}  {:>4}  {:>4}  \
                 {:>5}  {:>5}",
                df.label(),
                r.serving.tokens_per_s,
                r.serving.goodput_tokens_per_s,
                r.serving.ttft_p50_ms,
                r.serving.ttft_p95_ms,
                r.serving.ttft_p99_ms,
                r.serving.tpot_p95_ms,
                r.completed,
                r.expired,
                r.preemptions,
                r.dead_bands
            );
            r.serving.steps
        } else {
            let r = match try_simulate_with(&arch, &trace, &cfg, tel.as_mut()) {
                Ok(r) => r,
                Err(e) => return fail(&e.to_string()),
            };
            println!(
                "{:>9}  {:>10.0}  {:>10.0}  {:>9.3}  {:>9.3}  {:>9.4}  {:>9.4}  {:>8.1}%  \
                 {:>8.3}  {:>6}",
                df.label(),
                r.tokens_per_s,
                r.goodput_tokens_per_s,
                r.ttft_mean_ms,
                r.ttft_p95_ms,
                r.tpot_mean_ms,
                r.tpot_p95_ms,
                r.occupancy * 100.0,
                r.hbm_bytes as f64 / 1e9,
                r.steps
            );
            r.steps
        };
        if let Some(t) = &tel {
            let res = emit_telemetry(t, trace_out.as_deref(), metrics_out.as_deref(), steps);
            if let Err(e) = res {
                return fail(&e);
            }
        }
    }
    0
}

/// Write the telemetry artifacts requested on `schedule`: the chrome-trace
/// JSON (`--trace-out`), the Prometheus text snapshot (`--metrics-out`,
/// including the mode-dependent `engine_*` section), and the `--profile`
/// phase table on stdout.
fn emit_telemetry(
    tel: &RunTelemetry,
    trace_out: Option<&str>,
    metrics_out: Option<&str>,
    steps: usize,
) -> Result<(), String> {
    if let Some(path) = trace_out {
        let doc = tel.trace_json().expect("--trace-out enables the trace collector");
        std::fs::write(path, doc.to_string()).map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path} — open in chrome://tracing or Perfetto");
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, tel.metrics.to_prometheus(true))
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    if let Some(p) = &tel.profile {
        print!("{}", p.render(steps as u64));
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> i32 {
    let s = args.get_usize("seq", 256).unwrap_or(256);
    let d = args.get_usize("d", 64).unwrap_or(64);
    let g = args.get_usize("group", 4).unwrap_or(4);
    let mut rng = Rng::new(0xF1A7);
    let q = Tensor::randn(s, d, &mut rng);
    let k = Tensor::randn(s, d, &mut rng);
    let v = Tensor::randn(s, d, &mut rng);
    let golden = attention_golden(&q, &k, &v);

    if !args.flag("pjrt-only") {
        match run_flat_group_functional(&q, &k, &v, g, &NativeCompute) {
            Ok(res) => {
                let diff = res.output.max_abs_diff(&golden);
                println!(
                    "native  backend: {} block steps, max |diff| = {diff:.2e}",
                    res.block_steps
                );
                if diff > 1e-3 {
                    return fail("native functional validation FAILED");
                }
            }
            Err(e) => return fail(&format!("native run failed: {e}")),
        }
    }

    let dir = default_artifact_dir();
    if artifacts_available(&dir) {
        return validate_pjrt(&dir, &q, &k, &v, &golden, g, s, d);
    }
    println!(
        "artifacts not found in {} — skipping PJRT backend (run `make artifacts`)",
        dir.display()
    );
    0
}

#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
fn validate_pjrt(
    dir: &std::path::Path,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    golden: &Tensor,
    g: usize,
    s: usize,
    d: usize,
) -> i32 {
    let rt = match Runtime::new(dir.to_path_buf()) {
        Ok(rt) => rt,
        Err(e) => return fail(&format!("runtime start failed: {e}")),
    };
    println!("PJRT platform: {}", rt.platform());
    let compute = RuntimeCompute { runtime: &rt };
    match run_flat_group_functional(q, k, v, g, &compute) {
        Ok(res) => {
            let diff = res.output.max_abs_diff(golden);
            println!(
                "pjrt    backend: {} block steps, max |diff| = {diff:.2e}",
                res.block_steps
            );
            if diff > 5e-3 {
                return fail("PJRT functional validation FAILED");
            }
            println!("validation OK: Rust dataflow + AOT Pallas kernel reproduce attention");
            0
        }
        Err(e) => fail(&format!(
            "pjrt run failed (need block_step artifact r{0} c{0} d{d}): {e}",
            s / g
        )),
    }
}

#[cfg(not(feature = "pjrt"))]
#[allow(clippy::too_many_arguments)]
fn validate_pjrt(
    dir: &std::path::Path,
    _q: &Tensor,
    _k: &Tensor,
    _v: &Tensor,
    _golden: &Tensor,
    _g: usize,
    _s: usize,
    _d: usize,
) -> i32 {
    println!(
        "artifacts found in {} but this build has no PJRT support — add the `xla` crate to \
         rust/Cargo.toml [dependencies] and rebuild with `--features pjrt`",
        dir.display()
    );
    0
}

/// `flatattention lint` — sweep the structural verifier and roofline
/// cross-checker over dataflows × presets × fold modes, paged batch
/// composition and fault plans, printing one pass/fail row per case.
/// Exits non-zero if any case fails.
fn cmd_lint(args: &Args) -> i32 {
    use flatattention::analysis::{verify_batch, verify_fault_plan, verify_program, Roofline};
    use flatattention::dataflow::{
        build_program, run_faulted, set_symmetry_folding, symmetry_folding, tracked_tile,
        ALL_DATAFLOWS,
    };
    use flatattention::hbm::PageMap;
    use flatattention::scheduler::{compose, BatchEntry};
    use flatattention::sim::execute;

    let quick = args.flag("quick");
    // Each row is (case label, Ok(roofline utilization if computed) | Err(first diagnostic)).
    let mut rows: Vec<(String, Result<Option<f64>, String>)> = Vec::new();

    // Solo programs: presets × dataflows × folding.
    let presets_list: Vec<(&str, ArchConfig)> = if quick {
        vec![("table2-8", presets::table2(8))]
    } else {
        vec![("table2-8", presets::table2(8)), ("table1", presets::table1())]
    };
    let prev_folding = symmetry_folding();
    for (pname, arch) in &presets_list {
        let wl = Workload::new(32 * arch.mesh_y as u64, 64, 8, 1).with_causal(true);
        let group = arch.mesh_x;
        for df in ALL_DATAFLOWS {
            for fold in [true, false] {
                set_symmetry_folding(fold);
                let label = format!(
                    "{pname:<9} {:<9} fold={} solo",
                    df.label(),
                    if fold { "on " } else { "off" }
                );
                let mut p = build_program(arch, &wl, df, group);
                p.seal();
                if let Some(d) = verify_program(&p).first() {
                    rows.push((label, Err(d.to_string())));
                    continue;
                }
                let stats = execute(&p, tracked_tile(arch, df, group));
                match Roofline::of(arch, &wl, &p).check(stats.makespan) {
                    Ok(rep) => rows.push((label, Ok(Some(rep.utilization)))),
                    Err(d) => rows.push((label, Err(d.to_string()))),
                }
            }
        }
    }
    set_symmetry_folding(prev_folding);

    // Paged batch composition: two requests on disjoint tile bands
    // (chunked prefill + GQA decode), verified as a batch and roofline-
    // checked program-level (a composed batch has no single workload).
    let arch = presets::table2(8);
    let nch = arch.hbm.total_channels() as u64;
    let mut pm0 = PageMap::new(64);
    pm0.grow_to(256, |i| (i % nch) as u32);
    let mut pm1 = PageMap::new(64);
    pm1.grow_to(300, |i| ((i + 1) % nch) as u32);
    let entries = vec![
        BatchEntry {
            request: 0,
            slot: 0,
            workload: Workload::new(128, 64, 4, 1).with_causal(true).with_kv_prefix(128),
            pages: &pm0,
        },
        BatchEntry {
            request: 1,
            slot: 2,
            workload: Workload::new(300, 64, 4, 1).with_kv_heads(2).decode(),
            pages: &pm1,
        },
    ];
    for df in ALL_DATAFLOWS {
        let label = format!("table2-8  {:<9} paged batch", df.label());
        let bp = compose(&arch, df, 2, 4, &entries);
        if let Some(d) = verify_batch(&bp).first() {
            rows.push((label, Err(d.to_string())));
            continue;
        }
        let (stats, _) = bp.entry_stats();
        match Roofline::from_program(&arch, &bp.program).check(stats.makespan) {
            Ok(rep) => rows.push((label, Ok(Some(rep.utilization)))),
            Err(d) => rows.push((label, Err(d.to_string()))),
        }
    }

    // Layered batch composition: the projection/FFN GEMM tails ride each
    // entry's tile-row band, so `verify_batch`'s batch-tail rules apply,
    // and the program-level roofline must hold for GEMM-bearing programs
    // (the case `check_bench_targets.py` gates via the serving sweep).
    {
        use flatattention::scheduler::{compose_layered, LayerParams};
        let lp = LayerParams { ffn_mult: 2, weights: WeightResidency::HbmStream };
        for df in ALL_DATAFLOWS {
            let label = format!("table2-8  {:<9} layered batch", df.label());
            let bp = compose_layered(&arch, df, 2, 4, &entries, lp);
            if let Some(d) = verify_batch(&bp).first() {
                rows.push((label, Err(d.to_string())));
                continue;
            }
            let (stats, _) = bp.entry_stats();
            match Roofline::from_program(&arch, &bp.program).check(stats.makespan) {
                Ok(rep) => rows.push((label, Ok(Some(rep.utilization)))),
                Err(d) => rows.push((label, Err(d.to_string()))),
            }
        }
    }

    // Fault plans: sanity-check the plan itself, then confirm slow-only
    // faults (stretch, never remove work) still satisfy the fault-free
    // workload bounds. Killing plans are excluded from roofline checks.
    let channels = arch.hbm.total_channels();
    let tiles = arch.num_tiles();
    let fwl = Workload::new(256, 64, 8, 1).with_causal(true);
    let plans = [
        ("slow+noc", "slow:3@0-400000x2;noc@0-200000x3/2"),
        ("outage", "off:1@1000-30000"),
    ];
    for (name, spec) in plans {
        let label = format!("table2-8  fault plan '{name}'");
        let plan = match FaultPlan::parse(spec) {
            Ok(p) => p,
            Err(e) => {
                rows.push((label, Err(format!("parse: {e}"))));
                continue;
            }
        };
        if let Some(d) = verify_fault_plan(&plan, channels, tiles).first() {
            rows.push((label, Err(d.to_string())));
            continue;
        }
        let (stats, _report) =
            run_faulted(&arch, &fwl, Dataflow::FlatAsyn, arch.mesh_x, 1, &plan);
        match Roofline::from_workload(&arch, &fwl).check(stats.makespan) {
            Ok(rep) => rows.push((label, Ok(Some(rep.utilization)))),
            Err(d) => rows.push((label, Err(d.to_string()))),
        }
    }
    // A malformed plan must produce diagnostics (negative control).
    let mut bad = FaultPlan::none();
    bad.outages.push(flatattention::sim::fault::ChannelOutage {
        channel: 999,
        from: 10,
        until: 5,
    });
    let caught = !verify_fault_plan(&bad, channels, tiles).is_empty();
    rows.push((
        "table2-8  fault plan 'malformed' rejected".to_string(),
        if caught {
            Ok(None)
        } else {
            Err("verifier accepted an out-of-range, inverted outage window".to_string())
        },
    ));

    println!("flatattention lint — structural verifier + roofline cross-check");
    println!("{:<44} {:>9}  result", "case", "roofline");
    let mut failures = 0usize;
    for (label, res) in &rows {
        match res {
            Ok(Some(u)) => println!("{label:<44} {:>8.1}%  PASS", u * 100.0),
            Ok(None) => println!("{label:<44} {:>9}  PASS", "-"),
            Err(msg) => {
                failures += 1;
                println!("{label:<44} {:>9}  FAIL  {msg}", "-");
            }
        }
    }
    if failures > 0 {
        eprintln!("lint: {failures} of {} case(s) failed", rows.len());
        return 1;
    }
    println!("lint: all {} case(s) passed", rows.len());
    0
}

fn cmd_trace(args: &Args) -> i32 {
    let arch = match arch_from(args) {
        Ok(a) => a,
        Err(e) => return fail(&e),
    };
    let workload = match workload_from(args) {
        Ok(w) => w,
        Err(e) => return fail(&e),
    };
    let df_label = args.get_or("dataflow", "flatasyn");
    let Some(dataflow) = Dataflow::from_label(df_label) else {
        return fail(&format!("unknown dataflow '{df_label}'"));
    };
    let group = args.get_usize("group", arch.mesh_x.min(32)).unwrap_or(32);
    let tiles = args.get_usize("tiles", 64).unwrap_or(64) as u32;
    let out = args.get_or("out", "trace.json").to_string();

    // Build unfolded: symmetry folding collapses non-representative tiles'
    // compute into delay ops, and this observability tool exists precisely
    // to show every tile's real timeline.
    let prev_folding = flatattention::dataflow::symmetry_folding();
    flatattention::dataflow::set_symmetry_folding(false);
    let program = flatattention::dataflow::build_program(&arch, &workload, dataflow, group);
    flatattention::dataflow::set_symmetry_folding(prev_folding);
    let tracked = flatattention::dataflow::tracked_tile(&arch, dataflow, group);
    let (stats, records) = flatattention::sim::execute_traced(&program, tracked, Some(tiles));
    let json = flatattention::sim::trace::to_chrome_trace(&program, &records);
    if let Err(e) = std::fs::write(&out, json.to_string()) {
        return fail(&format!("writing {out}: {e}"));
    }
    println!(
        "wrote {out}: {} events over {} cycles ({} tiles traced) — open in chrome://tracing or Perfetto",
        records.len(),
        stats.makespan,
        tiles
    );
    0
}

fn cmd_info() -> i32 {
    for arch in [presets::table1(), presets::table2(16), presets::table2(8)] {
        println!("{}", arch.to_json().to_pretty());
    }
    println!(
        "artifacts dir: {} (available: {}, pjrt feature: {})",
        default_artifact_dir().display(),
        artifacts_available(&default_artifact_dir()),
        cfg!(feature = "pjrt")
    );
    println!("threads: {}", pool::default_threads());
    0
}

fn fail(msg: &str) -> i32 {
    eprintln!("error: {msg}");
    1
}
