//! On-chip network fabric model.
//!
//! Implements the paper's §II communication latency model for the 2-D mesh
//! NoC, including both software-based collectives (successive point-to-point
//! unicasts) and hardware-supported collectives (path-based in-flight
//! forwarding), plus XY-routing hop-count helpers used for tile↔HBM
//! distance accounting.

pub mod collective;
pub mod topology;

pub use collective::{
    collective_time, is_fabric_component, unicast_time, CollectiveKind, XferTime,
};
pub use topology::Topology;
