//! 2-D mesh topology helpers: coordinates, XY routing hop counts, and
//! tile↔HBM-channel edge distances.

/// Mesh topology of `x_dim × y_dim` tiles. Tile (0, 0) is the north-west
/// corner; HBM channels sit along the west (x = 0) and south (y = y_dim-1
/// side) edges per the paper's Fig. 1 floorplan. For distance purposes we
/// only need per-axis hop counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Tiles in x.
    pub x_dim: usize,
    /// Tiles in y.
    pub y_dim: usize,
}

impl Topology {
    /// A mesh of `x_dim × y_dim` tiles.
    pub fn new(x_dim: usize, y_dim: usize) -> Self {
        assert!(x_dim > 0 && y_dim > 0);
        Self { x_dim, y_dim }
    }

    /// Total tile count.
    pub fn num_tiles(&self) -> usize {
        self.x_dim * self.y_dim
    }

    /// Flat row-major tile id.
    pub fn id(&self, x: usize, y: usize) -> u32 {
        debug_assert!(x < self.x_dim && y < self.y_dim);
        (y * self.x_dim + x) as u32
    }

    /// Inverse of [`Topology::id`].
    pub fn coords(&self, id: u32) -> (usize, usize) {
        let id = id as usize;
        debug_assert!(id < self.num_tiles());
        (id % self.x_dim, id / self.x_dim)
    }

    /// XY-routing hop count between two tiles (Manhattan distance).
    pub fn hops(&self, a: u32, b: u32) -> u64 {
        let (ax, ay) = self.coords(a);
        let (bx, by) = self.coords(b);
        (ax.abs_diff(bx) + ay.abs_diff(by)) as u64
    }

    /// Hops from a tile to its west-edge HBM attachment point (row-aligned).
    pub fn hops_to_west_edge(&self, x: usize, _y: usize) -> u64 {
        x as u64
    }

    /// Hops from a tile to its south-edge HBM attachment point
    /// (column-aligned).
    pub fn hops_to_south_edge(&self, _x: usize, y: usize) -> u64 {
        (self.y_dim - 1 - y) as u64
    }

    /// Iterate all tile coordinates row-major.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let (xd, yd) = (self.x_dim, self.y_dim);
        (0..yd).flat_map(move |y| (0..xd).map(move |x| (x, y)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_coords_round_trip() {
        let t = Topology::new(32, 32);
        for (x, y) in [(0, 0), (31, 0), (0, 31), (17, 23)] {
            assert_eq!(t.coords(t.id(x, y)), (x, y));
        }
    }

    #[test]
    fn hops_manhattan() {
        let t = Topology::new(8, 8);
        assert_eq!(t.hops(t.id(0, 0), t.id(7, 7)), 14);
        assert_eq!(t.hops(t.id(3, 3), t.id(3, 3)), 0);
        assert_eq!(t.hops(t.id(1, 2), t.id(4, 2)), 3);
    }

    #[test]
    fn edge_distances() {
        let t = Topology::new(16, 16);
        assert_eq!(t.hops_to_west_edge(0, 5), 0);
        assert_eq!(t.hops_to_west_edge(15, 5), 15);
        assert_eq!(t.hops_to_south_edge(5, 15), 0);
        assert_eq!(t.hops_to_south_edge(5, 0), 15);
    }

    #[test]
    fn iter_covers_all_tiles() {
        let t = Topology::new(4, 3);
        let v: Vec<_> = t.iter().collect();
        assert_eq!(v.len(), 12);
        assert_eq!(v[0], (0, 0));
        assert_eq!(v[4], (0, 1));
        assert_eq!(v[11], (3, 2));
    }
}
