//! Collective-primitive latency model (paper §II).
//!
//! For a message of `α` bytes over links of `β` bytes/cycle, with
//! L1↔router injection latency `Ld` and per-hop router latency `Lr`,
//! reaching `N` destination tiles along a routing path:
//!
//! * **Software collective** (successive point-to-point unicasts, no fabric
//!   support): the source re-injects the message once per destination and
//!   the i-th destination is i hops away, giving a total latency of
//!   `N·(α/β + 2·Ld) + Σᵢ i·Lr  =  N·(α/β + 2·Ld + (N+1)/2·Lr)`.
//! * **Hardware collective** (path-based in-flight forwarding): each packet
//!   is duplicated/combined at the routers along the path, so the message
//!   is injected once: `α/β + 2·Ld + N·Lr`.
//!
//! Reductions traverse the same path in the reverse direction with
//! in-network combining and are modelled with the same cost (the combining
//! ALU operates at link rate); the software fallback performs sequential
//! gather transfers, again the same cost shape.
//!
//! The paper's §II example — α = 16 KB, β = 128 B/cycle, Ld = 10, Lr = 4,
//! N = 7 — yields a 6.1× hardware-vs-software latency reduction, which
//! [`tests::paper_example_6_1x`] pins down.

use crate::arch::NocConfig;
use crate::sim::Cycle;

/// What a collective does; timing is identical across kinds in this model,
/// but they are accounted to different breakdown components.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveKind {
    /// One-to-many replication along the group row/column.
    Multicast,
    /// Many-to-one max-combine (softmax running max).
    MaxReduce,
    /// Many-to-one sum-combine (softmax denominator / PV partials).
    SumReduce,
}

/// Split of a transfer's time into resource *occupancy* (serializes
/// back-to-back operations on the same path/port) and pipeline *latency*
/// (propagation; overlappable with independent work).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XferTime {
    /// Path-serializing cycles.
    pub occupancy: Cycle,
    /// Overlappable propagation cycles.
    pub latency: Cycle,
}

impl XferTime {
    /// `occupancy + latency`.
    pub fn total(&self) -> Cycle {
        self.occupancy + self.latency
    }
}

/// Time for a collective over `n_dest` destinations (chain length) with a
/// payload of `bytes`.
pub fn collective_time(noc: &NocConfig, bytes: u64, n_dest: u64, _kind: CollectiveKind) -> XferTime {
    if n_dest == 0 {
        // Degenerate 1-tile group: no communication.
        return XferTime { occupancy: 0, latency: 0 };
    }
    let serial = bytes.div_ceil(noc.link_bytes_per_cycle); // α/β
    let ld = noc.inject_latency;
    let lr = noc.router_latency;
    if noc.hw_collectives {
        // Path-based forwarding: inject once, per-hop duplication/combine.
        XferTime {
            occupancy: serial,
            latency: 2 * ld + n_dest * lr,
        }
    } else {
        // N successive unicasts; destination i is i hops from the source.
        // The source's injection port is busy the whole time, so the entire
        // cost is occupancy (it cannot pipeline with the next collective on
        // the same path).
        let sum_hops = n_dest * (n_dest + 1) / 2;
        XferTime {
            occupancy: n_dest * (serial + 2 * ld) + sum_hops * lr,
            latency: 0,
        }
    }
}

/// Point-to-point unicast over `hops` routers.
pub fn unicast_time(noc: &NocConfig, bytes: u64, hops: u64) -> XferTime {
    let serial = bytes.div_ceil(noc.link_bytes_per_cycle);
    XferTime {
        occupancy: serial,
        latency: 2 * noc.inject_latency + hops * noc.router_latency,
    }
}

/// True for accounting components that ride the NoC fabric (row/column
/// buses). `sim::fault` uses this to resolve a [`NoC slowdown`] window to
/// the bus resources of a concrete program: a bus is exactly a resource
/// whose ops carry one of these components.
///
/// [`NoC slowdown`]: crate::sim::fault::NocSlowdown
pub fn is_fabric_component(c: crate::sim::Component) -> bool {
    use crate::sim::Component;
    matches!(c, Component::Multicast | Component::MaxReduce | Component::SumReduce)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noc(hw: bool) -> NocConfig {
        NocConfig {
            link_bytes_per_cycle: 128,
            router_latency: 4,
            inject_latency: 10,
            hw_collectives: hw,
        }
    }

    /// §II worked example: α=16 KB, β=128 B/cyc, Ld=10, Lr=4, N=7 ⇒ 6.1×.
    #[test]
    fn paper_example_6_1x() {
        let bytes = 16 * 1024;
        let sw = collective_time(&noc(false), bytes, 7, CollectiveKind::Multicast).total();
        let hw = collective_time(&noc(true), bytes, 7, CollectiveKind::Multicast).total();
        // sw = 7*(128 + 20 + 4*4) = 7*(128+20) + 4*28 = 1148 cycles
        // hw = 128 + 20 + 7*4 = 176 cycles
        assert_eq!(sw, 7 * (128 + 20) + 4 * 28);
        assert_eq!(hw, 128 + 20 + 28);
        let ratio = sw as f64 / hw as f64;
        assert!((ratio - 6.1).abs() < 0.5, "ratio {ratio:.2} (paper: 6.1×)");
    }

    #[test]
    fn hw_collective_scales_weakly_with_destinations() {
        let n7 = collective_time(&noc(true), 16384, 7, CollectiveKind::Multicast).total();
        let n31 = collective_time(&noc(true), 16384, 31, CollectiveKind::Multicast).total();
        assert_eq!(n31 - n7, (31 - 7) * 4); // only Lr per extra hop
    }

    #[test]
    fn sw_collective_scales_linearly_plus_quadratic_hops() {
        let c = noc(false);
        let n1 = collective_time(&c, 1280, 1, CollectiveKind::Multicast).total();
        let n2 = collective_time(&c, 1280, 2, CollectiveKind::Multicast).total();
        // n1 = 10+20+4 = 34; n2 = 2*(10+20) + (1+2)*4 = 72
        assert_eq!(n1, 34);
        assert_eq!(n2, 72);
    }

    #[test]
    fn zero_destinations_is_free() {
        let t = collective_time(&noc(true), 4096, 0, CollectiveKind::SumReduce);
        assert_eq!(t.total(), 0);
    }

    #[test]
    fn unicast_includes_hop_latency() {
        let t = unicast_time(&noc(true), 256, 5);
        assert_eq!(t.occupancy, 2);
        assert_eq!(t.latency, 20 + 20);
    }

    #[test]
    fn sub_link_payload_rounds_up() {
        let t = unicast_time(&noc(true), 1, 0);
        assert_eq!(t.occupancy, 1);
    }
}
