"""Pallas kernel vs pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_kernel import block_step, flash_attention
from compile.kernels.ref import (
    attention_ref,
    attention_via_block_steps,
    block_step_ref,
)

jax.config.update("jax_enable_x64", False)


def randn(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestFlashAttention:
    @pytest.mark.parametrize("s,d,bq,bkv", [
        (128, 64, 64, 64),
        (256, 64, 128, 128),
        (256, 128, 128, 64),
        (512, 64, 128, 128),
        (128, 128, 128, 128),  # single block (degenerate grid)
    ])
    def test_matches_reference(self, s, d, bq, bkv):
        kq, kk, kv = keys(s * 7 + d, 3)
        q, k, v = randn(kq, s, d), randn(kk, s, d), randn(kv, s, d)
        out = flash_attention(q, k, v, block_q=bq, block_kv=bkv)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_cross_attention_lengths(self):
        # Sq != Skv exercises independent block clamping.
        kq, kk, kv = keys(11, 3)
        q, k, v = randn(kq, 128, 64), randn(kk, 256, 64), randn(kv, 256, 64)
        out = flash_attention(q, k, v, block_q=64, block_kv=128)
        np.testing.assert_allclose(out, attention_ref(q, k, v), rtol=2e-5, atol=2e-5)

    def test_rejects_ragged_blocks(self):
        kq, kk, kv = keys(1, 3)
        q, k, v = randn(kq, 100, 64), randn(kk, 100, 64), randn(kv, 100, 64)
        with pytest.raises(ValueError, match="divisible"):
            flash_attention(q, k, v, block_q=64, block_kv=64)

    def test_block_size_invariance(self):
        # The output must not depend on the block decomposition.
        kq, kk, kv = keys(3, 3)
        q, k, v = randn(kq, 256, 64), randn(kk, 256, 64), randn(kv, 256, 64)
        o1 = flash_attention(q, k, v, block_q=256, block_kv=256)
        o2 = flash_attention(q, k, v, block_q=64, block_kv=32)
        np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)

    def test_softmax_rows_bounded(self):
        # Output rows are convex combinations of V rows.
        kq, kk, kv = keys(5, 3)
        q, k, v = randn(kq, 128, 64), randn(kk, 128, 64), randn(kv, 128, 64)
        out = np.asarray(flash_attention(q, k, v))
        vmin, vmax = np.min(np.asarray(v), axis=0), np.max(np.asarray(v), axis=0)
        assert (out >= vmin - 1e-4).all()
        assert (out <= vmax + 1e-4).all()

    @settings(max_examples=20, deadline=None)
    @given(
        s_exp=st.integers(min_value=5, max_value=9),
        d=st.sampled_from([32, 64, 128]),
        bq_exp=st.integers(min_value=4, max_value=7),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, s_exp, d, bq_exp, seed):
        s = 2**s_exp
        bq = min(2**bq_exp, s)
        kq, kk, kv = keys(seed, 3)
        q, k, v = randn(kq, s, d), randn(kk, s, d), randn(kv, s, d)
        out = flash_attention(q, k, v, block_q=bq, block_kv=bq)
        np.testing.assert_allclose(out, attention_ref(q, k, v), rtol=3e-5, atol=3e-5)


class TestBlockStep:
    @pytest.mark.parametrize("br,bc,d", [(16, 16, 128), (64, 64, 64), (128, 128, 128), (32, 64, 64)])
    def test_matches_reference(self, br, bc, d):
        ks = keys(br * 131 + bc * 7 + d, 6)
        q, kt, v = randn(ks[0], br, d), randn(ks[1], d, bc), randn(ks[2], bc, d)
        m = randn(ks[3], br)
        l = jnp.abs(randn(ks[4], br)) + 0.5
        o = randn(ks[5], br, d)
        got = block_step(q, kt, v, m, l, o)
        want = block_step_ref(q, kt, v, m, l, o)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-5)

    def test_initial_state_neg_inf(self):
        # First step from (m=-inf, l=0, o=0) must be finite.
        ks = keys(42, 3)
        br, bc, d = 32, 32, 64
        q, kt, v = randn(ks[0], br, d), randn(ks[1], d, bc), randn(ks[2], bc, d)
        m = jnp.full((br,), -jnp.inf)
        l = jnp.zeros((br,))
        o = jnp.zeros((br, d))
        m2, l2, o2 = block_step(q, kt, v, m, l, o)
        assert np.isfinite(m2).all()
        assert (np.asarray(l2) > 0).all()
        assert np.isfinite(o2).all()

    def test_composition_equals_attention(self):
        # Iterating block_step over all K/V blocks == plain attention.
        ks = keys(7, 3)
        s, d, br, bc = 256, 64, 64, 64
        q, k, v = randn(ks[0], s, d), randn(ks[1], s, d), randn(ks[2], s, d)
        via_steps = attention_via_block_steps(q, k, v, br, bc)
        np.testing.assert_allclose(via_steps, attention_ref(q, k, v), rtol=2e-5, atol=2e-5)

    def test_permutation_invariance(self):
        # Online softmax must be invariant to K/V block order — the
        # property FlatAttention's group-parallel reduction relies on.
        ks = keys(9, 3)
        s, d, bc = 128, 64, 32
        q, k, v = randn(ks[0], 32, d), randn(ks[1], s, d), randn(ks[2], s, d)
        perm = np.random.RandomState(0).permutation(s // bc)

        def run(order):
            m = jnp.full((32,), -jnp.inf)
            l = jnp.zeros((32,))
            o = jnp.zeros((32, d))
            for j in order:
                kt = k[j * bc : (j + 1) * bc].T
                vj = v[j * bc : (j + 1) * bc]
                m, l, o = block_step(q, kt, vj, m, l, o)
            return o / l[:, None]

        o_fwd = run(range(s // bc))
        o_perm = run(perm)
        np.testing.assert_allclose(o_fwd, o_perm, rtol=3e-5, atol=3e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        br=st.sampled_from([16, 32, 64]),
        bc=st.sampled_from([16, 32, 64]),
        d=st.sampled_from([32, 64, 128]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_block_step(self, br, bc, d, seed):
        ks = keys(seed, 6)
        q, kt, v = randn(ks[0], br, d), randn(ks[1], d, bc), randn(ks[2], bc, d)
        m = randn(ks[3], br) * 0.5
        l = jnp.abs(randn(ks[4], br)) + 0.1
        o = randn(ks[5], br, d)
        got = block_step(q, kt, v, m, l, o)
        want = block_step_ref(q, kt, v, m, l, o)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=3e-5, atol=3e-5)


class TestCausal:
    @pytest.mark.parametrize("s,d,bq,bkv", [
        (128, 64, 64, 64),
        (256, 64, 64, 32),
        (256, 128, 128, 128),
    ])
    def test_causal_matches_reference(self, s, d, bq, bkv):
        kq, kk, kv = keys(s * 3 + d + 1, 3)
        q, k, v = randn(kq, s, d), randn(kk, s, d), randn(kv, s, d)
        out = flash_attention(q, k, v, block_q=bq, block_kv=bkv, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_causal_first_row_attends_self_only(self):
        kq, kk, kv = keys(77, 3)
        s, d = 128, 64
        q, k, v = randn(kq, s, d), randn(kk, s, d), randn(kv, s, d)
        out = flash_attention(q, k, v, block_q=64, block_kv=64, causal=True)
        # Row 0 can only attend to key 0 -> output row 0 == v[0].
        np.testing.assert_allclose(out[0], v[0], rtol=1e-5, atol=1e-5)

    def test_causal_cross_attention_right_aligned(self):
        kq, kk, kv = keys(78, 3)
        q, k, v = randn(kq, 64, 32), randn(kk, 128, 32), randn(kv, 128, 32)
        out = flash_attention(q, k, v, block_q=32, block_kv=32, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_causal_differs_from_noncausal(self):
        kq, kk, kv = keys(79, 3)
        s, d = 128, 64
        q, k, v = randn(kq, s, d), randn(kk, s, d), randn(kv, s, d)
        c = flash_attention(q, k, v, causal=True)
        nc = flash_attention(q, k, v, causal=False)
        assert not np.allclose(c, nc)
        # Last row sees everything: identical in both.
        np.testing.assert_allclose(c[-1], nc[-1], rtol=1e-5, atol=1e-5)
