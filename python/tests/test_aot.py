"""AOT lowering smoke tests: HLO text is produced and looks loadable."""

import json
import os

import pytest

from compile.aot import lower_block_step, lower_mha, main as aot_main


def test_block_step_hlo_text():
    text = lower_block_step(16, 16, 64)
    assert text.startswith("HloModule")
    # Tuple return of (m', l', o').
    assert "ROOT" in text
    assert "f32[16,64]" in text


def test_mha_hlo_text():
    text = lower_mha(1, 2, 128, 64)
    assert text.startswith("HloModule")
    assert "f32[1,2,128,64]" in text


def test_no_custom_calls_in_artifacts():
    # interpret=True must lower pallas to plain HLO the CPU PJRT client can
    # run — a mosaic/tpu custom-call would break the Rust runtime.
    for text in (lower_block_step(32, 32, 64), lower_mha(1, 1, 128, 64)):
        assert "custom-call" not in text or "mosaic" not in text.lower()


def test_aot_main_quick(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out-dir", str(tmp_path), "--quick"]
    )
    aot_main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest["block_step"]) >= 2
    assert len(manifest["mha"]) >= 1
    for entry in manifest["block_step"] + manifest["mha"]:
        p = tmp_path / entry["file"]
        assert p.exists() and p.stat().st_size > 100
