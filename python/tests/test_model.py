"""L2 model vs reference: batched MHA shapes and numerics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import mha_ref
from compile.model import mha, mha_with_pretranspose, transformer_layer_shapes


def rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)


@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 128, 64),
    (1, 4, 256, 64),
    (2, 2, 128, 128),
    (1, 2, 256, 128),
])
def test_mha_matches_reference(b, h, s, d):
    q, k, v = rand(1, b, h, s, d), rand(2, b, h, s, d), rand(3, b, h, s, d)
    out = mha(q, k, v)
    np.testing.assert_allclose(out, mha_ref(q, k, v), rtol=3e-5, atol=3e-5)


def test_mha_output_shape_and_dtype():
    q = k = v = rand(4, 1, 2, 128, 64)
    out = mha(q, k, v)
    assert out.shape == (1, 2, 128, 64)
    assert out.dtype == jnp.float32


def test_pretranspose_variant_identical():
    q, k, v = rand(5, 1, 2, 128, 64), rand(6, 1, 2, 128, 64), rand(7, 1, 2, 128, 64)
    np.testing.assert_allclose(
        mha_with_pretranspose(q, k, v), mha(q, k, v), rtol=1e-6, atol=1e-6
    )


def test_heads_are_independent():
    # Changing head 1's inputs must not affect head 0's output.
    q, k, v = rand(8, 1, 2, 128, 64), rand(9, 1, 2, 128, 64), rand(10, 1, 2, 128, 64)
    base = mha(q, k, v)
    q2 = q.at[:, 1].set(q[:, 1] * 2.0)
    out = mha(q2, k, v)
    np.testing.assert_allclose(out[:, 0], base[:, 0], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out[:, 1], base[:, 1])


def test_llama_layer_shapes():
    shapes = transformer_layer_shapes()
    assert shapes["ffn_down"] == (4096, 28672, 8192)
    assert shapes["o_proj"] == (4096, 8192, 8192)
