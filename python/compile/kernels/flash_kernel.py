"""L1: Pallas FlashAttention forward kernel (online softmax).

Hardware adaptation (DESIGN.md §3): the paper's per-tile slice maps to a
VMEM-resident Q block selected by the grid's BlockSpec; the Kᵀ/V stream the
paper moves with DMA + column multicast becomes a `fori_loop` over
VMEM-visible K/V blocks; RedMulE's output-stationary GEMM maps to the MXU
`jnp.dot`; the row statistics (m, l) of Algorithm 1/2 live in registers/
VMEM scratch. No warp-level constructs are needed — the tile L1 of the
paper *is* the VMEM of the Pallas model.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness (vs `ref.py`) is the build-time signal. The
real-hardware performance story lives in the Rust simulator.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_kv, scale, causal, skv_off):
    """One grid step: a Q block against the full (VMEM-visible) K/V.

    q_ref: [Bq, D]; k_ref, v_ref: [Skv, D]; o_ref: [Bq, D].

    With ``causal=True`` the loop stops after the diagonal K/V block and
    masks it (the same block-skipping the Rust dataflow builders model);
    ``skv_off = Skv - Sq`` right-aligns the mask for cross-attention.
    """
    q = q_ref[...]
    bq, d = q.shape
    skv = k_ref.shape[0]
    n_kv = skv // block_kv
    qi0 = pl.program_id(0) * bq  # global row offset of this Q block

    m0 = jnp.full((bq,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    o0 = jnp.zeros((bq, d), dtype=jnp.float32)

    def body(j, carry):
        m, l, o = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], j * block_kv, block_kv, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], j * block_kv, block_kv, axis=0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qi = qi0 + jnp.arange(bq)[:, None] + skv_off
            kj = j * block_kv + jnp.arange(block_kv)[None, :]
            s = jnp.where(kj <= qi, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # Rows that are still fully masked keep m = -inf; exp(-inf - -inf)
        # would be NaN, so alpha is forced to 0 there.
        alpha = jnp.where(m == -jnp.inf, 0.0, jnp.exp(m - m_new))
        p = jnp.where(jnp.isnan(p), 0.0, p)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        o_new = alpha[:, None] * o + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, o_new

    if causal:
        # Stop after the diagonal block of this Q block.
        last = (qi0 + bq - 1 + skv_off) // block_kv + 1
        n_iter = jnp.minimum(n_kv, last)
    else:
        n_iter = n_kv
    _, l, o = jax.lax.fori_loop(0, n_iter, body, (m0, l0, o0))
    o_ref[...] = (o / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, block_q=DEFAULT_BLOCK_Q, block_kv=DEFAULT_BLOCK_KV, causal=False):
    """Single-head FlashAttention forward: q [Sq, D], k/v [Skv, D].

    Blocks are clamped to the sequence lengths; sequence lengths must be
    multiples of the (clamped) block sizes.
    """
    sq, d = q.shape
    skv = k.shape[0]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    if sq % block_q or skv % block_kv:
        raise ValueError(f"S ({sq},{skv}) must be divisible by blocks ({block_q},{block_kv})")
    scale = 1.0 / float(d) ** 0.5

    kernel = functools.partial(
        _flash_kernel, block_kv=block_kv, scale=scale, causal=causal, skv_off=skv - sq
    )
    return pl.pallas_call(
        kernel,
        grid=(sq // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),  # Q block per step
            pl.BlockSpec((skv, d), lambda i: (0, 0)),      # full K stream
            pl.BlockSpec((skv, d), lambda i: (0, 0)),      # full V stream
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), q.dtype),
        interpret=True,
    )(q, k, v)


def _block_step_kernel(q_ref, kt_ref, v_ref, m_ref, l_ref, o_ref,
                       m_out, l_out, o_out, *, scale):
    """FlatAttention per-tile block step (Algorithm 2 lines 10-25).

    This is exactly the computation one tile performs per inner iteration
    between the NoC collectives; the Rust functional simulator executes
    the AOT-compiled version of this kernel as its tile compute.
    """
    q = q_ref[...]
    s = jnp.dot(q, kt_ref[...], preferred_element_type=jnp.float32) * scale
    m = m_ref[...]
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1)
    o_new = alpha[:, None] * o_ref[...] + jnp.dot(p, v_ref[...], preferred_element_type=jnp.float32)
    m_out[...] = m_new
    l_out[...] = l_new
    o_out[...] = o_new


def block_step(q, kt, v, m, l, o):
    """Online-softmax block update as a Pallas kernel.

    q: [Br, D], kt: [D, Bc], v: [Bc, D], m/l: [Br], o: [Br, D]
    -> (m', l', o').
    """
    br, d = q.shape
    scale = 1.0 / float(d) ** 0.5
    kernel = functools.partial(_block_step_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((br,), jnp.float32),
            jax.ShapeDtypeStruct((br,), jnp.float32),
            jax.ShapeDtypeStruct((br, d), jnp.float32),
        ),
        interpret=True,
    )(q, kt, v, m, l, o)
