"""Pure-jnp correctness oracles for the Pallas kernels.

These are the mathematical definitions the kernels must match (up to float
tolerance): plain softmax attention for the flash kernel, and the textbook
online-softmax block update (Algorithm 1 lines 7-18 of the paper) for the
FlatAttention per-tile block step.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, scale=None, causal=False):
    """softmax(Q Kᵀ · scale) V for a single head.

    q: [Sq, D], k: [Skv, D], v: [Skv, D] -> [Sq, D]

    With ``causal=True``, query i attends to keys j ≤ i + (Skv - Sq)
    (right-aligned causal mask).
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    s = (q @ k.T) * scale
    if causal:
        sq, skv = q.shape[0], k.shape[0]
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        kj = jnp.arange(skv)[None, :]
        s = jnp.where(kj <= qi, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def mha_ref(q, k, v):
    """Batched multi-head attention.

    q, k, v: [B, H, S, D] -> [B, H, S, D]
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def block_step_ref(q, kt, v, m, l, o, scale=None):
    """One online-softmax update step (unnormalized O accumulator).

    Given running statistics (m: row max, l: row denominator) and the
    unnormalized output accumulator o, fold in one K/V block:

        S    = (q @ kt) * scale
        m'   = max(m, rowmax(S))
        P    = exp(S - m')
        l'   = exp(m - m') * l + rowsum(P)
        o'   = diag(exp(m - m')) @ o + P @ v

    q: [Br, D], kt: [D, Bc], v: [Bc, D], m, l: [Br], o: [Br, D].
    Returns (m', l', o'). The caller normalizes by diag(l)^-1 at the end.
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    s = (q @ kt) * scale
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m - m_new)
    l_new = alpha * l + jnp.sum(p, axis=-1)
    o_new = alpha[:, None] * o + p @ v
    return m_new, l_new, o_new


def attention_via_block_steps(q, k, v, br, bc):
    """Reference composition: full attention out of block_step_ref calls.

    Validates that iterating the online-softmax block update over all K/V
    blocks reproduces plain attention — the invariant both the Pallas
    flash kernel and the Rust functional simulator rely on.
    """
    sq, d = q.shape
    skv = k.shape[0]
    assert sq % br == 0 and skv % bc == 0
    out = jnp.zeros_like(q)
    for i in range(0, sq, br):
        qi = q[i : i + br]
        m = jnp.full((br,), -jnp.inf, dtype=q.dtype)
        l = jnp.zeros((br,), dtype=q.dtype)
        o = jnp.zeros((br, d), dtype=q.dtype)
        for j in range(0, skv, bc):
            kt = k[j : j + bc].T
            vj = v[j : j + bc]
            m, l, o = block_step_ref(qi, kt, vj, m, l, o)
        out = out.at[i : i + br].set(o / l[:, None])
    return out
