"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text (not `HloModuleProto.serialize()`) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):
  block_step_r{Br}_c{Bc}_d{D}.hlo.txt   per-tile FlatAttention block step
                                        (the Rust functional simulator's
                                        tile compute), several slice shapes
  mha_b{B}_h{H}_s{S}_d{D}.hlo.txt       full multi-head attention forward
                                        (end-to-end golden model)
  manifest.json                         shape metadata for the Rust loader

Usage: python -m compile.aot [--out-dir DIR] [--quick]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.flash_kernel import block_step
from .model import mha

# Per-tile slice shapes (Br, Bc, D) exported for the functional simulator.
# These cover the slice sizes the Table-I architecture produces for the
# paper's workloads (S/G for G in {4..32}, D in {64, 128}).
BLOCK_STEP_SHAPES = [
    (16, 16, 128),
    (32, 32, 128),
    (64, 64, 64),
    (64, 64, 128),
    (128, 128, 64),
    (128, 128, 128),
]

# Full-MHA golden models (kept small: they execute at validation time).
MHA_SHAPES = [
    # (B, H, S, D)
    (1, 4, 256, 64),
    (1, 2, 256, 128),
]

QUICK_BLOCK_STEP_SHAPES = BLOCK_STEP_SHAPES[:2]
QUICK_MHA_SHAPES = MHA_SHAPES[:1]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_block_step(br: int, bc: int, d: int) -> str:
    args = (f32(br, d), f32(d, bc), f32(bc, d), f32(br), f32(br), f32(br, d))
    return to_hlo_text(jax.jit(block_step).lower(*args))


def lower_mha(b: int, h: int, s: int, d: int, block: int = 128) -> str:
    def fn(q, k, v):
        return (mha(q, k, v, block_q=min(block, s), block_kv=min(block, s)),)

    spec = f32(b, h, s, d)
    return to_hlo_text(jax.jit(fn).lower(spec, spec, spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    here = os.path.dirname(os.path.abspath(__file__))
    default_out = os.path.normpath(os.path.join(here, "..", "..", "artifacts"))
    ap.add_argument("--out-dir", default=default_out)
    ap.add_argument("--quick", action="store_true", help="emit a reduced artifact set")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    bs_shapes = QUICK_BLOCK_STEP_SHAPES if args.quick else BLOCK_STEP_SHAPES
    mha_shapes = QUICK_MHA_SHAPES if args.quick else MHA_SHAPES

    manifest = {"block_step": [], "mha": []}

    for br, bc, d in bs_shapes:
        name = f"block_step_r{br}_c{bc}_d{d}.hlo.txt"
        text = lower_block_step(br, bc, d)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        manifest["block_step"].append({"br": br, "bc": bc, "d": d, "file": name})
        print(f"wrote {name} ({len(text)} chars)")

    for b, h, s, d in mha_shapes:
        name = f"mha_b{b}_h{h}_s{s}_d{d}.hlo.txt"
        text = lower_mha(b, h, s, d)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        manifest["mha"].append({"b": b, "h": h, "s": s, "d": d, "file": name})
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
