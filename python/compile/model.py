"""L2: JAX model layer — batched multi-head attention over the L1 kernel.

Build-time only. `mha` composes the Pallas flash kernel over batch and
heads with `vmap`; `aot.py` lowers it (plus the per-tile `block_step`) to
HLO text for the Rust runtime. Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels.flash_kernel import block_step, flash_attention


def mha(q, k, v, block_q=128, block_kv=128):
    """Multi-head attention forward.

    q, k, v: [B, H, S, D] -> [B, H, S, D]
    """
    single = lambda q_, k_, v_: flash_attention(q_, k_, v_, block_q, block_kv)
    per_head = jax.vmap(single)       # over H
    per_batch = jax.vmap(per_head)    # over B
    return per_batch(q, k, v)


def mha_with_pretranspose(q, k, v, block_q=128, block_kv=128):
    """MHA including the K pre-transposition the paper accounts for when
    comparing against H100 (§III footnote 2, §V-C): K is stored
    pre-transposed in HBM; the transposition cost is charged to the layer.
    In the compute graph this is a layout round-trip the compiler may fuse;
    the simulator charges its HBM traffic separately."""
    kt = jnp.swapaxes(k, -1, -2)
    return mha(q, jnp.swapaxes(kt, -1, -2), v, block_q, block_kv)


def flat_block_step(q, kt, v, m, l, o):
    """Per-tile FlatAttention block update (Algorithm 2 inner loop) —
    exported per slice shape for the Rust functional simulator."""
    return block_step(q, kt, v, m, l, o)


def transformer_layer_shapes(hidden=8192, ffn=28672, seq=4096):
    """GEMM shapes of a LLaMA-70B-style layer (Fig. 5c workloads)."""
    return {
        "qkv_proj": (seq, hidden, 3 * hidden // 8 * 8),
        "o_proj": (seq, hidden, hidden),
        "ffn_up_gate": (seq, hidden, 2 * ffn),
        "ffn_down": (seq, ffn, hidden),
    }
