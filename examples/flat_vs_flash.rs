//! FlatAttention vs FlashAttention head-to-head (the paper's Fig. 3 story)
//! with the headline claims computed live.
//!
//!     cargo run --release --example flat_vs_flash [-- <seq> <head_dim>]

use flatattention::arch::presets;
use flatattention::coordinator::{run_all, ExperimentSpec};
use flatattention::dataflow::{Dataflow, Workload, ALL_DATAFLOWS};
use flatattention::util::pool;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seq: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(4096);
    let d: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128);

    let arch = presets::table1();
    let wl = Workload::new(seq, d, 32, 2);
    println!("comparing dataflows on {} — {} (H=32, B=2, G=32x32)\n", arch.name, wl.label());

    let specs: Vec<ExperimentSpec> = ALL_DATAFLOWS
        .into_iter()
        .map(|df| ExperimentSpec { arch: arch.clone(), workload: wl, dataflow: df, group: 32 })
        .collect();
    let results = run_all(&specs, pool::default_threads());

    println!(
        "{:<10} {:>12} {:>9} {:>10} {:>9}",
        "dataflow", "runtime", "util", "HBM", "BW util"
    );
    for r in &results {
        println!(
            "{:<10} {:>9.3} ms {:>8.1}% {:>7.2} GB {:>8.1}%",
            r.dataflow.label(),
            r.runtime_ms,
            r.utilization * 100.0,
            r.hbm_bytes as f64 / 1e9,
            r.hbm_bw_util * 100.0
        );
    }

    let fa3 = results.iter().find(|r| r.dataflow == Dataflow::Flash3).unwrap();
    let flat = results.iter().find(|r| r.dataflow == Dataflow::FlatAsyn).unwrap();
    println!(
        "\nFlatAsyn vs FA-3: {:.1}x speedup, {:.1}x HBM traffic reduction, {:.1}% utilization",
        fa3.makespan as f64 / flat.makespan as f64,
        fa3.hbm_bytes as f64 / flat.hbm_bytes as f64,
        flat.utilization * 100.0
    );
    println!("(paper, D128/S4096: 4.1x speedup, 16x traffic reduction, up to 89.3% utilization)");
}
