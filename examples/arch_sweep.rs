//! Architecture × dataflow co-exploration (the paper's §V-C methodology):
//! sweep fabric granularity and HBM connectivity at iso-peak performance,
//! pick BestArch, and report its die area.
//!
//!     cargo run --release --example arch_sweep

use flatattention::arch::area::{AreaModel, H100_DIE_MM2};
use flatattention::arch::presets;
use flatattention::dataflow::Workload;
use flatattention::report::fig5a;
use flatattention::report::ReportOpts;
use flatattention::util::pool;

fn main() {
    let opts = ReportOpts { quick: false, threads: pool::default_threads() };
    println!("co-exploring fabric granularity x HBM channels (iso 1024 TFLOPS)...\n");
    let cells = fig5a::run(&opts);

    println!(
        "{:<24} {:>6} {:>10} {:>10} {:>14}",
        "architecture", "tiles", "HBM ch", "avg util", "best dataflow"
    );
    for c in &cells {
        println!(
            "{:<24} {:>6} {:>10} {:>9.1}% {:>11} g{}",
            c.arch.name,
            c.arch.num_tiles(),
            c.arch.hbm.total_channels(),
            c.utilization * 100.0,
            c.best_dataflow,
            c.best_group
        );
    }

    let best = cells
        .iter()
        .max_by(|a, b| a.utilization.partial_cmp(&b.utilization).unwrap())
        .unwrap();
    let area = AreaModel::default().estimate(&best.arch);
    println!("\nBestArch: {}", best.arch.name);
    println!("  avg utilization {:.1}%", best.utilization * 100.0);
    println!(
        "  die area {:.0} mm² (logic {:.0} + SRAM {:.0}, 66% util) — {:.1}x smaller than H100",
        area.total_mm2,
        area.logic_mm2,
        area.sram_mm2,
        H100_DIE_MM2 / area.total_mm2
    );

    // Show the per-sequence-length optimum on BestArch (§V-B).
    println!("\nper-sequence-length optimal group on BestArch (FlatAsyn):");
    let arch = presets::best_arch();
    for s in [512u64, 1024, 2048, 4096] {
        let wl = Workload::new(s, 128, 32, 4);
        let r = flatattention::coordinator::best_group(
            &arch,
            &wl,
            flatattention::dataflow::Dataflow::FlatAsyn,
            opts.threads,
        );
        println!(
            "  S={s:<5} group {0}x{0}  util {1:.1}%  runtime {2:.3} ms",
            r.group,
            r.utilization * 100.0,
            r.runtime_ms
        );
    }
}
