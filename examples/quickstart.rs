//! Quickstart: simulate one MHA layer with FlatAttention on the paper's
//! Table I accelerator and print the runtime breakdown.
//!
//!     cargo run --release --example quickstart

use flatattention::arch::presets;
use flatattention::dataflow::{run, Dataflow, FlatTiling, Workload};
use flatattention::sim::breakdown::ALL_COMPONENTS;

fn main() {
    // The paper's headline layer: S=4096, D=128, H=32, B=2.
    let arch = presets::table1();
    let wl = Workload::new(4096, 128, 32, 2);
    let group = 32; // one group spanning the whole 32×32 mesh

    println!("architecture : {} ({} tiles, {:.0} TFLOPS peak)", arch.name, arch.num_tiles(), arch.peak_tflops());
    println!("workload     : {} (H={}, B={})", wl.label(), wl.heads, wl.batch);

    let tiling = FlatTiling::resolve(&arch, wl.head_dim, wl.seq, group, true);
    println!(
        "tiling       : {}x{} slice per tile, group block {}, T_r={}, T_c={}",
        tiling.slice, tiling.slice, tiling.block, tiling.t_r, tiling.t_c
    );

    let stats = run(&arch, &wl, Dataflow::FlatAsyn, group);
    println!("\nruntime      : {:.3} ms ({} cycles @ {} GHz)", stats.runtime_ms(arch.freq_ghz), stats.makespan, arch.freq_ghz);
    println!(
        "utilization  : {:.1}% of peak ({:.0} TFLOPS achieved)",
        stats.compute_utilization(arch.peak_flops_per_cycle()) * 100.0,
        stats.compute_utilization(arch.peak_flops_per_cycle()) * arch.peak_tflops()
    );
    println!(
        "HBM traffic  : {:.2} GB ({:.1}% of peak bandwidth)",
        stats.hbm_bytes as f64 / 1e9,
        stats.hbm_bw_utilization(arch.hbm.peak_bytes_per_cycle()) * 100.0
    );
    println!("\nper-component breakdown on the critical tile:");
    for c in ALL_COMPONENTS {
        let cycles = stats.breakdown.get(c);
        println!(
            "  {:<10} {:>12} cycles  {:>5.1}%",
            c.label(),
            cycles,
            cycles as f64 / stats.makespan as f64 * 100.0
        );
    }
}
