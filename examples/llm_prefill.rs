//! End-to-end driver: LLaMA-70B-style transformer-layer prefill on
//! BestArch, composing every layer of the stack:
//!
//! 1. *Functional*: attention numerics run through the full three-layer
//!    path — the Rust group dataflow moves real data and the per-tile
//!    compute is the AOT-compiled Pallas `block_step` kernel executed via
//!    PJRT — and are checked against the golden reference.
//! 2. *Performance*: the same layer's compute (MHA via FlatAttention +
//!    QKV/O/FFN GEMMs via collective SUMMA) is simulated on the Table I /
//!    BestArch accelerator, reporting per-kernel and full-prefill runtime,
//!    utilization, and HBM traffic — the paper's headline metrics.
//!
//!     make artifacts && cargo run --release --example llm_prefill

use flatattention::arch::presets;
use flatattention::coordinator::best_group;
use flatattention::dataflow::summa::{summa_program, GemmWorkload};
use flatattention::dataflow::{Dataflow, Workload};
use flatattention::functional::{attention_golden, run_flat_group_functional, RuntimeCompute};
use flatattention::runtime::{default_artifact_dir, Runtime};
use flatattention::sim::execute;
use flatattention::util::{pool, Rng, Tensor};

fn main() {
    let arch = presets::best_arch();
    println!("=== end-to-end LLaMA-70B-style prefill on {} ===\n", arch.name);

    // ---------------------------------------------------------------
    // Part 1 — functional validation through PJRT (small real workload).
    // ---------------------------------------------------------------
    let dir = default_artifact_dir();
    if Runtime::available(&dir) {
        let rt = Runtime::new(dir).expect("PJRT runtime");
        println!("[functional] PJRT platform: {}", rt.platform());
        let (s, d, g) = (256usize, 64usize, 2usize);
        let mut rng = Rng::new(0xE2E);
        let q = Tensor::randn(s, d, &mut rng);
        let k = Tensor::randn(s, d, &mut rng);
        let v = Tensor::randn(s, d, &mut rng);
        let compute = RuntimeCompute { runtime: &rt };
        let res = run_flat_group_functional(&q, &k, &v, g, &compute).expect("group run");
        let diff = res.output.max_abs_diff(&attention_golden(&q, &k, &v));
        println!(
            "[functional] FlatAttention group {g}x{g} over S={s}, D={d}: {} compiled block steps, max |diff| vs golden = {diff:.2e}",
            res.block_steps
        );
        assert!(diff < 2e-3, "functional validation failed");
        println!("[functional] OK — Rust dataflow + AOT Pallas kernel reproduce attention\n");
    } else {
        println!("[functional] artifacts missing — run `make artifacts` first (skipping PJRT check)\n");
    }

    // ---------------------------------------------------------------
    // Part 2 — full prefill performance on the simulated accelerator.
    // LLaMA-70B: hidden 8192, ffn 28672, 64 heads (D=128), 80 layers,
    // GQA ignored (worst case), prefill S=4096, B=1.
    // ---------------------------------------------------------------
    let (hidden, ffn, s, heads, d) = (8192u64, 28672u64, 4096u64, 64u64, 128u64);
    let threads = pool::default_threads();

    // MHA via FlatAttention with the optimal group.
    let mha = Workload::new(s, d, heads, 1);
    let mha_best = best_group(&arch, &mha, Dataflow::FlatAsyn, threads);

    // Projections + FFN via collective SUMMA.
    let gemms = [
        GemmWorkload::new(s, hidden, 3 * hidden, "qkv-proj"),
        GemmWorkload::new(s, hidden, hidden, "o-proj"),
        GemmWorkload::new(s, hidden, 2 * ffn, "ffn-up+gate"),
        GemmWorkload::new(s, ffn, hidden, "ffn-down"),
    ];

    println!("[prefill] per-kernel results (S={s}, hidden={hidden}, ffn={ffn}):");
    println!(
        "  {:<12} {:>12} {:>9} {:>10}",
        "kernel", "runtime", "util", "HBM"
    );
    let mut total_cycles = mha_best.makespan;
    let mut total_bytes = mha_best.hbm_bytes;
    let mut total_flops = mha.matmul_flops();
    println!(
        "  {:<12} {:>9.3} ms {:>8.1}% {:>7.2} GB   (FlatAsyn, group {}x{})",
        "attention",
        mha_best.runtime_ms,
        mha_best.utilization * 100.0,
        mha_best.hbm_bytes as f64 / 1e9,
        mha_best.group,
        mha_best.group
    );
    for g in &gemms {
        let stats = execute(&summa_program(&arch, g), 0);
        let util = stats.compute_utilization(arch.peak_flops_per_cycle());
        println!(
            "  {:<12} {:>9.3} ms {:>8.1}% {:>7.2} GB   (SUMMA)",
            g.label,
            stats.runtime_ms(arch.freq_ghz),
            util * 100.0,
            stats.hbm_bytes as f64 / 1e9
        );
        total_cycles += stats.makespan;
        total_bytes += stats.hbm_bytes;
        total_flops += g.flops();
    }

    let layers = 80u64;
    let layer_ms = total_cycles as f64 / (arch.freq_ghz * 1e9) * 1e3;
    let layer_util = total_flops as f64 / (total_cycles as f64 * arch.peak_flops_per_cycle() as f64);
    println!("\n[prefill] one transformer layer: {layer_ms:.3} ms, {:.1}% utilization, {:.2} GB HBM traffic", layer_util * 100.0, total_bytes as f64 / 1e9);
    println!(
        "[prefill] {layers}-layer model prefill: {:.1} ms, {:.1} TFLOP total, {:.0} TFLOPS sustained",
        layer_ms * layers as f64,
        total_flops as f64 * layers as f64 / 1e12,
        total_flops as f64 / (total_cycles as f64 / (arch.freq_ghz * 1e9)) / 1e12
    );
    println!(
        "[prefill] headline: attention utilization {:.1}% (paper: up to 89.3%)",
        mha_best.utilization * 100.0
    );
}
