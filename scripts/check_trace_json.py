#!/usr/bin/env python3
"""Structurally validate a chrome-trace JSON exported by `flatattention`.

Usage:

    python3 scripts/check_trace_json.py TRACE.json

Checks the shape every consumer (chrome://tracing, Perfetto, and
tests/telemetry_determinism.rs's reconciliation pass) relies on:

  - top level is an object with a non-empty "traceEvents" array and a
    "displayTimeUnit" of "ms" or "ns" (this repo always writes "ms" —
    see the time-unit convention in rust/src/telemetry/events.rs);
  - every event is an object with a non-empty "name", a "ph" in
    {X, i, I, M}, and integer "pid"/"tid" >= 0;
  - complete events (ph=X) carry integer "ts" and "dur" >= 0, and within
    each (pid, tid) lane they are sorted by ts and non-overlapping
    (chrome://tracing silently mis-renders overlapping X slices);
  - instants (ph=i/I) carry an integer "ts" >= 0;
  - at least one metadata event (ph=M) names a process.

Exits non-zero with one line per violation. CI's rust-analysis job runs
this on the trace exported by the `schedule --trace-out` smoke.
"""

import json
import sys


def fail(msgs):
    print("TRACE VALIDATION FAILED:", file=sys.stderr)
    for m in msgs:
        print(f"  {m}", file=sys.stderr)
    sys.exit(1)


def is_count(v):
    return isinstance(v, int) and not isinstance(v, bool) and v >= 0


def main():
    if len(sys.argv) != 2:
        print("usage: check_trace_json.py TRACE.json", file=sys.stderr)
        sys.exit(2)
    path = sys.argv[1]
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail([f"{path}: unreadable ({e})"])

    errors = []
    if not isinstance(doc, dict):
        fail([f"{path}: top level must be an object, got {type(doc).__name__}"])
    unit = doc.get("displayTimeUnit")
    if unit not in ("ms", "ns"):
        errors.append(f"displayTimeUnit must be 'ms' or 'ns', got {unit!r}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(errors + ["traceEvents must be a non-empty array"])

    lanes = {}  # (pid, tid) -> [(ts, dur, name)]
    meta = 0
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        name = e.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing or empty 'name'")
        ph = e.get("ph")
        if ph not in ("X", "i", "I", "M"):
            errors.append(f"{where} ({name}): unknown ph {ph!r}")
            continue
        if not is_count(e.get("pid")) or not is_count(e.get("tid")):
            errors.append(f"{where} ({name}): pid/tid must be integers >= 0")
            continue
        if ph == "M":
            meta += 1
            continue
        if not is_count(e.get("ts")):
            errors.append(f"{where} ({name}): ph={ph} needs an integer ts >= 0")
            continue
        if ph == "X":
            if not is_count(e.get("dur")):
                errors.append(f"{where} ({name}): ph=X needs an integer dur >= 0")
                continue
            lanes.setdefault((e["pid"], e["tid"]), []).append((e["ts"], e["dur"], name))

    if meta == 0:
        errors.append("no metadata events (ph=M): process names are missing")

    for (pid, tid), slices in sorted(lanes.items()):
        prev_end, prev_name = None, None
        for ts, dur, name in slices:
            if prev_end is not None and ts < prev_end:
                errors.append(
                    f"lane pid={pid} tid={tid}: '{name}' at ts={ts} overlaps "
                    f"'{prev_name}' ending at {prev_end} (unsorted or overlapping X slices)"
                )
            prev_end, prev_name = ts + dur, name

    if errors:
        fail(errors)
    n_slices = sum(len(s) for s in lanes.values())
    print(
        f"{path}: ok — {len(events)} events, {n_slices} slices across "
        f"{len(lanes)} lanes, {meta} metadata records, displayTimeUnit={unit}"
    )


if __name__ == "__main__":
    main()
