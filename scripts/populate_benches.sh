#!/usr/bin/env bash
# Refresh the three BENCH_*.json reports at the repo root by actually
# running the benches, exactly as CI's rust-bench job does, then assert
# the in-bench targets. The JSONs started life as placeholders ("no Rust
# toolchain in the authoring container"); this script is how they get —
# and stay — populated.
#
#   scripts/populate_benches.sh            # full-size benches
#   BENCH_SMOKE=1 scripts/populate_benches.sh   # CI-sized reduced configs
set -euo pipefail
cd "$(dirname "$0")/.."

for bench in sim_hotpath serving_sweep schedule_sweep; do
    echo "=== cargo bench --bench $bench ${BENCH_SMOKE:+(BENCH_SMOKE=$BENCH_SMOKE)}"
    (cd rust && cargo bench --bench "$bench")
done

python3 scripts/check_bench_targets.py
echo "BENCH_sim_hotpath.json, BENCH_serving_sweep.json, BENCH_schedule_sweep.json refreshed."
