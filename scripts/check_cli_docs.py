#!/usr/bin/env python3
"""Keep docs/CLI.md in sync with the binary's --help output.

Extracts every `--flag` token (and every subcommand named on a
`flatattention <sub>` usage line) from the help text and from docs/CLI.md
and diffs the two sets, in both directions. CI runs this in the
`rust-analysis` job; a flag added to the parser must be added both to
`print_usage()` and to docs/CLI.md before this passes.

Usage:
    check_cli_docs.py [HELP_FILE]

HELP_FILE is a file containing the output of `flatattention --help`
(CI captures one with `cargo run --release --quiet -- --help`). Without
the argument, the script runs `cargo run` itself from rust/ — handy
locally, but it requires a toolchain and a built target.
"""

from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CLI_DOC = ROOT / "docs" / "CLI.md"

# `--` followed by a letter, then letters/digits with single-dash
# separators. The lookbehind rejects the inner dashes of `---` markdown
# table rules; requiring a leading letter rejects `---` itself.
FLAG_RE = re.compile(r"(?<![-\w])--([a-z][a-z0-9]*(?:-[a-z0-9]+)*)")

# Flags that intentionally appear on only one side of the diff.
IGNORE = {
    "help",  # --help is how the help text is obtained; usage omits it
    "release",  # cargo's own flags, quoted in invocation examples
    "quiet",
}


def flags_in(text: str) -> set[str]:
    return {m.group(1) for m in FLAG_RE.finditer(text)} - IGNORE


def subcommands_in_help(text: str) -> set[str]:
    return {
        m.group(1)
        for m in re.finditer(r"^\s*flatattention\s+([a-z]+)\b", text, re.M)
    }


def help_text(argv: list[str]) -> str:
    if len(argv) > 1:
        return Path(argv[1]).read_text(encoding="utf-8")
    proc = subprocess.run(
        ["cargo", "run", "--release", "--quiet", "--", "--help"],
        cwd=ROOT / "rust",
        capture_output=True,
        text=True,
        check=True,
    )
    return proc.stdout


def main(argv: list[str]) -> int:
    help_txt = help_text(argv)
    doc_txt = CLI_DOC.read_text(encoding="utf-8")

    help_flags = flags_in(help_txt)
    doc_flags = flags_in(doc_txt)
    failures = []

    undocumented = sorted(help_flags - doc_flags)
    if undocumented:
        failures.append(
            "flags in --help but missing from docs/CLI.md: "
            + ", ".join("--" + f for f in undocumented)
        )
    phantom = sorted(doc_flags - help_flags)
    if phantom:
        failures.append(
            "flags documented in docs/CLI.md but absent from --help: "
            + ", ".join("--" + f for f in phantom)
        )

    missing_subs = sorted(
        s for s in subcommands_in_help(help_txt)
        if f"flatattention {s}" not in doc_txt
    )
    if missing_subs:
        failures.append(
            "subcommands in --help but missing from docs/CLI.md: "
            + ", ".join(missing_subs)
        )

    if failures:
        for f in failures:
            print(f"check_cli_docs: FAIL: {f}")
        return 1
    print(
        f"check_cli_docs: OK ({len(help_flags)} flags, "
        f"{len(subcommands_in_help(help_txt))} subcommands in sync)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
