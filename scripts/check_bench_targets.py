#!/usr/bin/env python3
"""Assert every in-bench acceptance target recorded in the BENCH_*.json
reports at the repo root.

Run after the benches (CI's rust-bench job does, with BENCH_SMOKE=1;
scripts/populate_benches.sh does locally):

    python3 scripts/check_bench_targets.py

Targets (mirroring the asserts/WARNINGs inside the bench harnesses):

  sim_hotpath     e2e_speedup            >= 2.0
                  fold_e2e_speedup       >= 3.0
                  parallel_e2e_speedup   >= 2.0 at 8 threads — skipped when
                                         parallel_cores_available < 3 (on a
                                         1-2 core runner, >= 2x point-level
                                         fan-out is arithmetically out of
                                         reach; the metric is still recorded)
  serving_sweep   decode_mqa_traffic_reduction >= 10.0
                  decode_over_prefill_makespan <= 0.1
                  layer_pipeline_utilization   in (0, 1.0]: mesh occupancy of
                                         the layered serving replay (full
                                         transformer layers per step, requests
                                         pipelined across bands at different
                                         layer depths)
                  layer_roofline_utilization   in (0, 1.0]: roofline check of a
                                         GEMM-bearing composed layer program
                                         (attention + projection/FFN tails)
  schedule_sweep  continuous_over_static_*     >= 1.5 (every dataflow row)
                  degraded_over_faultfree_tokens_per_s >= 0.6 (router keeps
                                         most throughput with 1/8 of the
                                         HBM channels at half bandwidth)
                  step_compose_speedup   >= 5.0 (incremental compose +
                                         memoized delta re-simulation vs a
                                         full per-step rebuild on the
                                         recurring-shape stream)
                  synthetic_stream_requests_per_s >= 1000 (the >= 1M-request
                                         synthetic replay completes and is
                                         bounded by the scheduler loop,
                                         not the DES; smoke runs a scaled
                                         stream, recorded honestly in
                                         synthetic_stream_requests)
                  telemetry_overhead     >= 0.95 (off/on wall-clock ratio of
                                         the mixed-trace replay: a full
                                         telemetry sink — windowed metrics +
                                         lifecycle trace — may cost at most
                                         ~5%)
                  memo_hit_rate          present (composer solo-memo hits /
                  patch_hit_rate         lookups and patched / patch-eligible
                                         steps, read from the sink's engine_
                                         counters; recorded for trend
                                         tracking, only presence is gated)
  all three       roofline_utilization   in (0, 1.0]: the analytical lower
                                         bound (analysis::Roofline) never
                                         exceeds the simulated run time —
                                         utilization above 1.0 means the
                                         simulator beat the hardware's
                                         roofline, i.e. a modeling bug

Exits non-zero listing every violated target; placeholder files (empty
"metrics") fail loudly — the point of the CI job is that the benches RAN.
"""

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
failures = []
notes = []


def load(name):
    path = ROOT / name
    try:
        with open(path, encoding="utf-8") as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        failures.append(f"{name}: unreadable ({e})")
        return {}
    metrics = report.get("metrics", {})
    if not metrics:
        failures.append(f"{name}: no recorded metrics (placeholder? run the bench first)")
    return metrics


def require(name, metrics, key, lo=None, hi=None):
    if key not in metrics:
        failures.append(f"{name}: metric '{key}' missing")
        return
    v = metrics[key]
    if lo is not None and v < lo:
        failures.append(f"{name}: {key} = {v:.3f} below target {lo}")
    elif hi is not None and v > hi:
        failures.append(f"{name}: {key} = {v:.3f} above target {hi}")
    else:
        bound = f">= {lo}" if lo is not None else f"<= {hi}"
        notes.append(f"{name}: {key} = {v:.3f} (target {bound}) ok")


hot = load("BENCH_sim_hotpath.json")
if hot:
    require("sim_hotpath", hot, "e2e_speedup", lo=2.0)
    require("sim_hotpath", hot, "fold_e2e_speedup", lo=3.0)
    cores = hot.get("parallel_cores_available", 0)
    if cores >= 3:
        require("sim_hotpath", hot, "parallel_e2e_speedup", lo=2.0)
    elif "parallel_e2e_speedup" in hot:
        notes.append(
            f"sim_hotpath: parallel_e2e_speedup = {hot['parallel_e2e_speedup']:.3f} "
            f"recorded but not gated ({cores:.0f} cores available < 3)"
        )
    else:
        failures.append("sim_hotpath: metric 'parallel_e2e_speedup' missing")

srv = load("BENCH_serving_sweep.json")
if srv:
    require("serving_sweep", srv, "decode_mqa_traffic_reduction", lo=10.0)
    require("serving_sweep", srv, "decode_over_prefill_makespan", hi=0.1)
    require("serving_sweep", srv, "layer_pipeline_utilization", lo=1e-9, hi=1.0)
    require("serving_sweep", srv, "layer_roofline_utilization", lo=1e-9, hi=1.0)

sch = load("BENCH_schedule_sweep.json")
if sch:
    rows = [k for k in sch if k.startswith("continuous_over_static_")]
    if not rows:
        failures.append("schedule_sweep: no continuous_over_static_* metrics")
    for k in rows:
        require("schedule_sweep", sch, k, lo=1.5)
    require("schedule_sweep", sch, "degraded_over_faultfree_tokens_per_s", lo=0.6)
    require("schedule_sweep", sch, "step_compose_speedup", lo=5.0)
    require("schedule_sweep", sch, "synthetic_stream_requests_per_s", lo=1000.0)
    require("schedule_sweep", sch, "telemetry_overhead", lo=0.95)
    # Hit rates are trend metrics: any value in [0, 1] passes, absence fails.
    require("schedule_sweep", sch, "memo_hit_rate", lo=0.0)
    require("schedule_sweep", sch, "patch_hit_rate", lo=0.0)

# Roofline soundness: every bench records its utilization against the
# analytical lower bound; > 1.0 would mean the simulated run undercut the
# roofline (the benches also assert this in-process, but the gate catches
# a report produced by an older binary).
for label, metrics in (("sim_hotpath", hot), ("serving_sweep", srv), ("schedule_sweep", sch)):
    if metrics:
        require(label, metrics, "roofline_utilization", lo=1e-9, hi=1.0)

for line in notes:
    print(line)
if failures:
    print("\nBENCH TARGETS FAILED:", file=sys.stderr)
    for line in failures:
        print(f"  {line}", file=sys.stderr)
    sys.exit(1)
print("\nall bench targets met")
